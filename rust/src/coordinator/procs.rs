//! Multi-process training: one OS process per sub-model, shard files as
//! the only exchange medium.
//!
//! The paper's central claim is that sub-models train **fully
//! asynchronously with zero parameter synchronization**. The in-process
//! [`super::leader`] realizes that with reducer threads sharing an
//! address space; this module promotes it to actual OS processes:
//!
//! * [`run_worker`] — the body of the `dw2v train-worker` subcommand.
//!   Trains exactly one sub-model, streaming sentences from on-disk
//!   `shard_*.bin` files through a [`ShardFileSource`] (peak corpus
//!   memory: one sentence) and routing them with the same stateless
//!   counter-based [`Divider`](super::divider::Divider) the leader uses.
//!   Because routing is a pure function of `(seed, strategy, rate,
//!   epoch, sentence index)`, workers need **no coordination at
//!   training time at all** — no parameter server (Ordentlich et al.),
//!   no sync barriers (Ji et al.), not even a socket. The finished
//!   sub-model is published as a versioned [`SubModelArtifact`]
//!   (write-to-temp + rename, so a killed worker can never leave a
//!   half-written artifact behind).
//! * [`spawn_workers`] / [`WorkerPool`] / [`run_multiprocess`] — the
//!   coordinator: spawns `100/r` workers via `std::process::Command`,
//!   monitors them as they exit, collects whatever artifacts came back
//!   and runs the shared merge + eval tail
//!   ([`super::leader::merge_and_eval`]) over the survivors.
//!
//! **Fault tolerance is the point, not an afterthought**: a crashed or
//! killed worker's sub-model is simply absent, and the merge proceeds
//! over the survivors — the paper's missing-*words* robustness
//! (§reconstruction) promoted to missing-*sub-models* robustness. The
//! failure is surfaced in the [`WorkerOutcome`]s, never hidden.
//!
//! ## Determinism
//!
//! A worker derives its trainer seed, divider and lr-schedule
//! denominator through the same shared helpers as the in-process leader
//! ([`super::leader::submodel_seed`], [`super::leader::run_divider`],
//! [`super::leader::submodel_expected_pairs`]), and global sentence
//! indices over the shard files match the in-memory corpus by
//! construction. With `mappers = 1` (deterministic delivery order into
//! the single reducer) a multi-process run therefore produces sub-models
//! **bitwise identical** to the in-process leader path on the native
//! backend; with more mappers the two paths are statistically equivalent
//! (same data, same routing, different macro-batch boundaries).
//!
//! Test hook: a worker sleeps `DW2V_WORKER_STARTUP_SLEEP_MS`
//! milliseconds before touching the shards when that variable is set —
//! the kill-a-worker e2e uses it to open a deterministic window in which
//! a victim can be SIGKILLed mid-run.

use super::leader;
use super::mapper::{ShardFileSource, SubModelFilter};
use super::reducer::TrainReducer;
use crate::embedding::{ArtifactMeta, Embedding, SubModelArtifact};
use crate::exec::mapreduce::MapReduce;
use crate::gen::benchmarks::Benchmark;
use crate::info;
use crate::runtime::{load_backend, Backend};
use crate::sgns::schedule::PairEstimator;
use crate::sgns::trainer::SubModelTrainer;
use crate::text::vocab::Vocab;
use crate::util::config::ExperimentConfig;
use crate::util::logging::Timer;
use std::path::PathBuf;
use std::process::{Child, Command, ExitStatus};
use std::sync::Arc;
use std::time::Instant;

// ---------------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------------

/// What one `dw2v train-worker` invocation trains and where it puts it.
pub struct WorkerSpec {
    /// directory of `shard_*.bin` + `vocab.tsv`
    pub shard_dir: PathBuf,
    /// sub-model index in `0..100/r`
    pub submodel: usize,
    /// artifact output path
    pub out: PathBuf,
}

/// Train one sub-model in this process — the whole worker protocol.
/// Streams the corpus from `spec.shard_dir`, trains sub-model
/// `spec.submodel` and atomically publishes a [`SubModelArtifact`] at
/// `spec.out`. Any error (unreadable shards, bad index, backend failure)
/// is returned, which the CLI turns into a non-zero exit the coordinator
/// records as a failed worker.
pub fn run_worker(cfg: &ExperimentConfig, spec: &WorkerSpec) -> Result<(), String> {
    if let Ok(ms) = std::env::var("DW2V_WORKER_STARTUP_SLEEP_MS") {
        if let Ok(ms) = ms.parse::<u64>() {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
    let vocab_path = spec.shard_dir.join("vocab.tsv");
    let vocab_text = std::fs::read_to_string(&vocab_path)
        .map_err(|e| format!("read {}: {e}", vocab_path.display()))?;
    let vocab = Vocab::from_tsv(&vocab_text)?;
    if vocab.is_empty() {
        return Err(format!("{} holds an empty vocabulary", vocab_path.display()));
    }
    let source = ShardFileSource::open(&spec.shard_dir)?;
    let total = source.total_sentences();
    if total == 0 {
        return Err(format!(
            "shards in {} hold no sentences",
            spec.shard_dir.display()
        ));
    }

    let divider = Arc::new(leader::run_divider(cfg, total)?);
    if spec.submodel >= divider.num_submodels {
        return Err(format!(
            "sub-model index {} out of range: rate {}% implies {} sub-models",
            spec.submodel, cfg.rate_percent, divider.num_submodels
        ));
    }

    // estimation pass: stream the corpus once to compute the lr-schedule
    // denominator exactly as the in-process leader does over the
    // in-memory corpus (same sentence order ⇒ bitwise-identical sum)
    let scfg = leader::sgns_config(cfg);
    let mut est = PairEstimator::new(&vocab, &scfg);
    {
        use crate::exec::mapreduce::RoundSource;
        for (_, sentence) in source.shard(0, 0, 1) {
            est.add_sentence(&sentence);
        }
    }
    if let Some(e) = source.take_error() {
        return Err(format!("estimation pass failed: {e}"));
    }
    let expected_pairs = leader::submodel_expected_pairs(cfg, est.per_epoch(), &divider, total);
    let trainer_seed = leader::submodel_seed(cfg.seed, spec.submodel);

    let backend = load_backend(cfg, vocab.len())?;
    info!(
        "worker {}: {} sentences in {} shard files, {} epochs, expected ~{} pairs, backend {}",
        spec.submodel,
        total,
        source.num_files(),
        cfg.epochs,
        expected_pairs,
        backend.name()
    );

    let trainer = SubModelTrainer::new(&backend, &vocab, &scfg, expected_pairs, trainer_seed)?;
    let mut reducers = vec![TrainReducer::new(trainer)];
    let timer = Timer::start("worker train");
    let mr = MapReduce {
        num_mappers: cfg.mappers.max(1),
        queue_capacity: cfg.queue_capacity,
    };
    let submodel = spec.submodel;
    mr.run(
        cfg.epochs,
        &source,
        |epoch, _shard| SubModelFilter::new(Arc::clone(&divider), epoch, submodel),
        &mut reducers,
    );
    let train_secs = timer.stop_quiet();
    if let Some(e) = source.take_error() {
        return Err(format!("shard streaming failed mid-train: {e}"));
    }
    let red = reducers.pop().expect("one reducer");
    if let Some(e) = red.error {
        return Err(format!("trainer failed: {e}"));
    }

    let pairs = red.trainer.pairs_emitted();
    let epoch_loss = red.epoch_mean_loss.clone();
    let sentences = red.trainer.sentences_received;
    let embedding = red.trainer.into_embedding(cfg.submodel_min_count())?;
    let artifact = SubModelArtifact {
        meta: ArtifactMeta {
            submodel: spec.submodel,
            num_submodels: divider.num_submodels,
            root_seed: cfg.seed,
            trainer_seed,
            strategy: cfg.strategy.name().to_string(),
            rate_percent: cfg.rate_percent,
            epochs: cfg.epochs,
            pairs,
            epoch_loss,
        },
        embedding,
    };
    // write-then-rename: the coordinator must never observe a partial
    // artifact, even if this process dies mid-save
    let tmp = spec.out.with_extension("tmp");
    artifact
        .save(&tmp)
        .map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, &spec.out)
        .map_err(|e| format!("publish {}: {e}", spec.out.display()))?;
    info!(
        "worker {}: done in {train_secs:.2}s — {sentences} sentences, {pairs} pairs, artifact {}",
        spec.submodel,
        spec.out.display()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// coordinator side
// ---------------------------------------------------------------------------

/// How the coordinator spawns its workers.
pub struct ProcsOptions {
    /// the `dw2v` binary to execute (see [`find_worker_exe`])
    pub worker_exe: PathBuf,
    /// directory of `shard_*.bin` + `vocab.tsv` the workers stream
    pub shard_dir: PathBuf,
    /// where worker artifacts (and the run's `config.json`) land
    pub out_dir: PathBuf,
    /// extra environment for the workers (test hooks; empty in production)
    pub extra_env: Vec<(String, String)>,
}

/// Why a worker produced no usable sub-model.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkerFate {
    /// exited 0 and its artifact loaded and matched the run config
    Completed,
    /// crashed, was killed, exited non-zero, or published a bad artifact
    Failed(String),
}

impl std::fmt::Display for WorkerFate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkerFate::Completed => write!(f, "ok"),
            WorkerFate::Failed(why) => write!(f, "FAILED — {why}"),
        }
    }
}

/// One worker's result as the coordinator saw it.
pub struct WorkerOutcome {
    pub submodel: usize,
    /// wall-clock from spawn to exit
    pub secs: f64,
    pub fate: WorkerFate,
    /// present iff `fate` is `Completed`
    pub artifact: Option<SubModelArtifact>,
}

impl WorkerOutcome {
    pub fn survived(&self) -> bool {
        self.artifact.is_some()
    }
}

struct WorkerChild {
    submodel: usize,
    child: Child,
    out: PathBuf,
    /// `Ok(status)` once the child was reaped, `Err(why)` if it became
    /// unwaitable; plus seconds since pool start
    finished: Option<(Result<ExitStatus, String>, f64)>,
}

/// Live handle on a set of spawned workers. Obtained from
/// [`spawn_workers`]; [`Self::wait`] monitors them to completion. The
/// split (rather than one blocking call) exists so callers — the
/// kill-a-worker e2e above all — can reach the children (e.g.
/// [`Self::pid`]) while they run.
pub struct WorkerPool {
    children: Vec<WorkerChild>,
    started: Instant,
    root_seed: u64,
    num_submodels: usize,
}

fn describe_status(status: &ExitStatus) -> String {
    if status.success() {
        return "ok".to_string();
    }
    if let Some(code) = status.code() {
        return format!("exit code {code}");
    }
    #[cfg(unix)]
    {
        use std::os::unix::process::ExitStatusExt;
        if let Some(sig) = status.signal() {
            return format!("killed by signal {sig}");
        }
    }
    "terminated abnormally".to_string()
}

/// Spawn one `train-worker` process per sub-model. The experiment config
/// is passed as a `config.json` in `out_dir` plus an explicit `--seed`
/// override (u64 seeds don't survive a JSON f64 round trip above 2^53).
pub fn spawn_workers(
    cfg: &ExperimentConfig,
    opts: &ProcsOptions,
) -> Result<WorkerPool, String> {
    // validate before num_submodels(): a rate of 0 would saturate the
    // count to usize::MAX and the spawn loop below would fork-bomb the
    // host long before any worker's Divider::new could reject it
    crate::util::config::validate_rate_percent(cfg.rate_percent)?;
    let n = cfg.num_submodels();
    if !opts.shard_dir.join("vocab.tsv").is_file() {
        return Err(format!(
            "{} has no vocab.tsv — persist a corpus first (gen-corpus, or --text with --shard-dir)",
            opts.shard_dir.display()
        ));
    }
    // fail fast on an unreadable corpus before paying n process spawns
    let probe = ShardFileSource::open(&opts.shard_dir)?;
    std::fs::create_dir_all(&opts.out_dir)
        .map_err(|e| format!("create {}: {e}", opts.out_dir.display()))?;
    let config_path = opts.out_dir.join("config.json");
    // the seed is re-encoded as a decimal string: u64s above 2^53 don't
    // survive a JSON f64 round trip, and `apply` parses strings exactly
    let mut config_json = cfg.to_json();
    if let crate::util::json::Json::Obj(o) = &mut config_json {
        o.insert(
            "seed".to_string(),
            crate::util::json::Json::Str(cfg.seed.to_string()),
        );
    }
    std::fs::write(&config_path, config_json.to_string_pretty())
        .map_err(|e| format!("write {}: {e}", config_path.display()))?;

    info!(
        "coordinator: spawning {n} workers over {} shard files ({} sentences), exe {}",
        probe.num_files(),
        probe.total_sentences(),
        opts.worker_exe.display()
    );
    let mut children = Vec::with_capacity(n);
    let started = Instant::now();
    for s in 0..n {
        let out = opts.out_dir.join(format!("submodel_{s}.dwsm"));
        // stale artifacts from a previous run in the same out_dir must not
        // masquerade as this run's output if the worker dies before
        // publishing
        let _ = std::fs::remove_file(&out);
        let mut cmd = Command::new(&opts.worker_exe);
        cmd.arg("train-worker")
            .arg("--config")
            .arg(&config_path)
            .arg("--seed")
            .arg(cfg.seed.to_string())
            .arg("--shard-dir")
            .arg(&opts.shard_dir)
            .arg("--submodel")
            .arg(s.to_string())
            .arg("--out")
            .arg(&out);
        for (k, v) in &opts.extra_env {
            cmd.env(k, v);
        }
        let child = match cmd.spawn() {
            Ok(c) => c,
            Err(e) => {
                // don't leak the workers already launched: left alone they
                // would train the whole run and drop artifacts into
                // out_dir that a later identically-configured run could
                // mistake for its own
                for mut wc in children {
                    let _ = wc.child.kill();
                    let _ = wc.child.wait();
                }
                return Err(format!(
                    "spawn worker {s} ({}): {e}",
                    opts.worker_exe.display()
                ));
            }
        };
        children.push(WorkerChild {
            submodel: s,
            child,
            out,
            finished: None,
        });
    }
    Ok(WorkerPool {
        children,
        started,
        root_seed: cfg.seed,
        num_submodels: n,
    })
}

impl WorkerPool {
    /// OS pid of a still-tracked worker.
    pub fn pid(&self, submodel: usize) -> Option<u32> {
        self.children
            .iter()
            .find(|c| c.submodel == submodel)
            .map(|c| c.child.id())
    }

    /// Monitor the workers to completion: poll every few milliseconds,
    /// log each exit as it happens, then validate and collect the
    /// artifacts of the workers that exited cleanly. Returns the
    /// per-worker outcomes plus the wall-clock of the whole train phase.
    pub fn wait(mut self) -> (Vec<WorkerOutcome>, f64) {
        let mut pending = self.children.len();
        while pending > 0 {
            pending = 0;
            for wc in self.children.iter_mut() {
                if wc.finished.is_some() {
                    continue;
                }
                match wc.child.try_wait() {
                    Ok(Some(status)) => {
                        let secs = self.started.elapsed().as_secs_f64();
                        info!(
                            "coordinator: worker {} exited after {secs:.2}s ({})",
                            wc.submodel,
                            describe_status(&status)
                        );
                        wc.finished = Some((Ok(status), secs));
                    }
                    Ok(None) => pending += 1,
                    Err(e) => {
                        // an unwaitable child counts as a failed worker
                        let secs = self.started.elapsed().as_secs_f64();
                        wc.finished = Some((Err(format!("wait failed: {e}")), secs));
                    }
                }
            }
            if pending > 0 {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
        let train_secs = self.started.elapsed().as_secs_f64();
        let (root_seed, n) = (self.root_seed, self.num_submodels);
        let outcomes = self
            .children
            .into_iter()
            .map(|wc| {
                let (status, secs) = wc.finished.expect("all children waited");
                let clean = matches!(&status, Ok(st) if st.success());
                let (fate, artifact) = if !clean {
                    let why = match &status {
                        Ok(st) => describe_status(st),
                        Err(e) => e.clone(),
                    };
                    (WorkerFate::Failed(why), None)
                } else {
                    match SubModelArtifact::load(&wc.out) {
                        Ok(a) => {
                            if a.meta.submodel != wc.submodel
                                || a.meta.root_seed != root_seed
                                || a.meta.num_submodels != n
                            {
                                (
                                    WorkerFate::Failed(format!(
                                        "artifact {} belongs to a different run \
                                         (submodel {} of {}, root seed {})",
                                        wc.out.display(),
                                        a.meta.submodel,
                                        a.meta.num_submodels,
                                        a.meta.root_seed
                                    )),
                                    None,
                                )
                            } else {
                                (WorkerFate::Completed, Some(a))
                            }
                        }
                        Err(e) => (
                            WorkerFate::Failed(format!(
                                "exited ok but artifact unreadable: {e}"
                            )),
                            None,
                        ),
                    }
                };
                WorkerOutcome {
                    submodel: wc.submodel,
                    secs,
                    fate,
                    artifact,
                }
            })
            .collect();
        (outcomes, train_secs)
    }
}

/// Result of a full multi-process run.
pub struct ProcsReport {
    /// per-worker fates, in sub-model order — failures included
    pub outcomes: Vec<WorkerOutcome>,
    /// wall-clock from first spawn to last worker exit
    pub train_secs: f64,
    /// the shared merge + eval tail over the surviving sub-models
    pub tail: leader::MergeEvalOutput,
}

impl ProcsReport {
    pub fn survivors(&self) -> usize {
        self.outcomes.iter().filter(|o| o.survived()).count()
    }

    pub fn failures(&self) -> impl Iterator<Item = &WorkerOutcome> {
        self.outcomes.iter().filter(|o| !o.survived())
    }
}

/// The full multi-process pipeline: spawn `100/r` workers, wait for
/// them, merge + eval whatever came back. Errors only when **no** worker
/// survived — any smaller set of failures degrades gracefully into a
/// merge over the survivors (the paper's robustness claim, promoted to
/// sub-model granularity).
pub fn run_multiprocess(
    cfg: &ExperimentConfig,
    suite: &[Benchmark],
    opts: &ProcsOptions,
) -> Result<ProcsReport, String> {
    let pool = spawn_workers(cfg, opts)?;
    let (mut outcomes, train_secs) = pool.wait();
    // move the embeddings out of the artifacts for the merge — cloning
    // them would double coordinator peak memory (sub-models can be GBs) —
    // and put them back afterwards so the report's artifacts stay whole
    let submodels: Vec<Embedding> = outcomes
        .iter_mut()
        .filter_map(|o| o.artifact.as_mut())
        .map(|a| std::mem::replace(&mut a.embedding, Embedding::zeros(0, 1)))
        .collect();
    if submodels.is_empty() {
        let detail: Vec<String> = outcomes
            .iter()
            .map(|o| format!("worker {}: {}", o.submodel, o.fate))
            .collect();
        return Err(format!(
            "all {} workers failed — nothing to merge:\n  {}",
            outcomes.len(),
            detail.join("\n  ")
        ));
    }
    let survivors = submodels.len();
    if survivors < outcomes.len() {
        info!(
            "coordinator: merging {survivors}/{} sub-models (the rest failed)",
            outcomes.len()
        );
    }
    let tail = leader::merge_and_eval(cfg, &submodels, suite);
    let mut returned = submodels.into_iter();
    for a in outcomes.iter_mut().filter_map(|o| o.artifact.as_mut()) {
        a.embedding = returned.next().expect("one embedding per survivor");
    }
    Ok(ProcsReport {
        outcomes,
        train_secs,
        tail,
    })
}

/// Locate the `dw2v` binary to use as the worker executable:
/// `DW2V_WORKER_EXE` if set, the current executable when it *is* `dw2v`
/// (the CLI case), else a `dw2v` sibling of the current executable or of
/// its parent directory (the `target/<profile>/examples/…` case).
pub fn find_worker_exe() -> Result<PathBuf, String> {
    if let Ok(p) = std::env::var("DW2V_WORKER_EXE") {
        let p = PathBuf::from(p);
        if p.is_file() {
            return Ok(p);
        }
        return Err(format!("DW2V_WORKER_EXE={} does not exist", p.display()));
    }
    let me = std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?;
    let name = format!("dw2v{}", std::env::consts::EXE_SUFFIX);
    if me.file_name().and_then(|n| n.to_str()) == Some(name.as_str()) {
        return Ok(me);
    }
    for dir in [me.parent(), me.parent().and_then(|d| d.parent())]
        .into_iter()
        .flatten()
    {
        let candidate = dir.join(&name);
        if candidate.is_file() {
            return Ok(candidate);
        }
    }
    Err(format!(
        "cannot locate the dw2v binary next to {} — build it (`cargo build --bin dw2v`) \
         or set DW2V_WORKER_EXE",
        me.display()
    ))
}
