//! Multi-process training: one OS process per sub-model, shard files as
//! the only exchange medium.
//!
//! The paper's central claim is that sub-models train **fully
//! asynchronously with zero parameter synchronization**. The in-process
//! [`super::leader`] realizes that with reducer threads sharing an
//! address space; this module promotes it to actual OS processes:
//!
//! * [`run_worker`] — the body of the `dw2v train-worker` subcommand.
//!   Trains exactly one sub-model, streaming sentences from on-disk
//!   `shard_*.bin` files through a [`ShardFileSource`] (peak corpus
//!   memory: one sentence) and routing them with the same stateless
//!   counter-based [`Divider`](super::divider::Divider) the leader uses.
//!   Because routing is a pure function of `(seed, strategy, rate,
//!   epoch, sentence index)`, workers need **no coordination at
//!   training time at all** — no parameter server (Ordentlich et al.),
//!   no sync barriers (Ji et al.), not even a socket. The finished
//!   sub-model is published as a versioned [`SubModelArtifact`]
//!   (write-to-temp + rename, so a killed worker can never leave a
//!   half-written artifact behind).
//! * [`spawn_workers`] / [`WorkerPool`] / [`run_multiprocess`] — the
//!   plain coordinator: spawns `100/r` workers via
//!   `std::process::Command`, monitors them as they exit, collects
//!   whatever artifacts came back and runs the shared merge + eval tail
//!   ([`super::leader::merge_and_eval`]) over the survivors.
//! * [`super::supervisor::run_supervised`] — the supervised coordinator
//!   built from the pieces this module exports ([`prepare_run`],
//!   [`spawn_one_worker`], [`collect_artifact`]): beacon-based liveness,
//!   stall detection, and policy-driven respawn from checkpoints.
//!
//! **Fault tolerance is the point, not an afterthought**: a crashed or
//! killed worker's sub-model is simply absent, and the merge proceeds
//! over the survivors — the paper's missing-*words* robustness
//! (§reconstruction) promoted to missing-*sub-models* robustness. The
//! failure is surfaced in the [`WorkerOutcome`]s, never hidden.
//!
//! ## Worker protocol (one artifact dir, five file kinds)
//!
//! Everything a worker says to the coordinator is a file in `out_dir`,
//! always published write-to-temp + rename:
//!
//! * `config.json` — the run config, written once by the coordinator.
//! * `beacon_<s>.json` — the worker's heartbeat/progress beacon
//!   ([`super::supervisor::BeaconWriter`]): rewritten every
//!   `DW2V_BEACON_INTERVAL_MS` (default 250 ms; a malformed override is
//!   a startup error, mirroring `DW2V_FAULT`). Phases run
//!   `start → estimate → train → done`, plus `waiting` in feed mode
//!   whenever the worker is blocked on an unpublished shard. Each write
//!   bumps a sequence number, so the supervisor can treat **any byte
//!   change** as liveness — a worker parked in `waiting` while ingest
//!   catches up is healthy, not stalled.
//! * `feedstat_<s>.json` — feed mode only: how many shards the manifest
//!   listed when this worker opened its [`ShardFeed`]
//!   (`shards_at_train_start`), the final shard count, and how many
//!   polls blocked. The overlap e2e reads it to prove training really
//!   did start before ingest finished.
//! * `submodel_<s>.ckpt` — an epoch-boundary [`CheckpointArtifact`]:
//!   packed trainer state + exact counters. Written after every epoch
//!   except the last (the artifact itself supersedes it) and deleted on
//!   successful publication. A respawned worker finds it, validates it
//!   against the run identity, and resumes at the recorded epoch.
//! * `submodel_<s>.dwsm` — the final [`SubModelArtifact`].
//!
//! [`prepare_run`] deletes stale worker-output files of every kind (plus
//! fault-injection markers) before a new run spawns anything, so output
//! from an older run in the same dir can never masquerade as this run's.
//!
//! ## Determinism
//!
//! A worker derives its trainer seed, divider and lr-schedule
//! denominator through the same shared helpers as the in-process leader
//! ([`super::leader::submodel_seed`], [`super::leader::run_divider`],
//! [`super::leader::submodel_expected_pairs`]), and global sentence
//! indices over the shard files match the in-memory corpus by
//! construction. With `mappers = 1` (deterministic delivery order into
//! the single reducer) a multi-process run therefore produces sub-models
//! **bitwise identical** to the in-process leader path on the native
//! backend; with more mappers the two paths are statistically equivalent
//! (same data, same routing, different macro-batch boundaries).
//!
//! Checkpoint/resume preserves that determinism: the Divider is a
//! stateless counter (routing needs only the epoch in the packed sid),
//! the batch builder's base RNG never advances, and the checkpoint
//! carries the exact f64 loss counters — so a worker crashed at an epoch
//! boundary and respawned resumes into the *same* pair stream and
//! finishes bitwise identical to an uninterrupted run (the chaos e2e
//! pins this).
//!
//! ## Feed mode (ingest/training overlap)
//!
//! With `DW2V_FEED=1` in the environment ([`FEED_ENV`], set on the whole
//! fleet via [`ProcsOptions::extra_env`] by the overlap driver), a worker
//! trains from a [`ShardFeed`] instead of the up-front
//! [`ShardFileSource`] snapshot: it waits for the overlapped ingest's
//! schedule block (`waiting` beacons), takes `total_sentences` and the
//! lr-schedule denominator from the manifest instead of running its own
//! estimation pass — the ingest computed them over the identically
//! encoded stream, so the values are bitwise the ones a post-hoc pass
//! would produce — and then streams shards as they are published.
//! Global sentence indices are identical to the snapshot path by
//! construction, so an overlapped run merges bitwise identical to a
//! back-to-back ingest-then-train on the native backend.
//!
//! ## Test hooks
//!
//! * `DW2V_WORKER_STARTUP_SLEEP_MS` — sleep before touching the shards
//!   (opens a deterministic window for the kill-a-worker e2e).
//! * `DW2V_FAULT` — deterministic fault injection, parsed by
//!   [`super::supervisor::FaultSpec`] (`crash@pairs=N`, `stall@epoch=K`,
//!   `corrupt-artifact`, `slow@factor=F`, each optionally scoped with
//!   `@submodel=S`; clauses joined with `;`).
//! * `DW2V_BEACON_INTERVAL_MS` — beacon publish interval override; a
//!   value that doesn't parse as whole milliseconds is a loud startup
//!   error, never a silent fallback to the default.

use super::leader;
use super::mapper::{ShardFileSource, SubModelFilter, SID_INDEX_BITS};
use super::reducer::TrainReducer;
use super::supervisor::{ArmedFaults, BeaconWriter, FaultSpec};
use crate::embedding::{
    ArtifactMeta, CheckpointArtifact, CheckpointMeta, Embedding, SubModelArtifact,
};
use crate::exec::mapreduce::{MapReduce, Reducer, RoundSource};
use crate::gen::benchmarks::Benchmark;
use crate::info;
use crate::obs::journal::u64s;
use crate::runtime::params::Metrics;
use crate::runtime::{load_backend, Backend};
use crate::sgns::schedule::PairEstimator;
use crate::sgns::trainer::{SubModelTrainer, TrainerSnapshot};
use crate::text::feed::{self, FeedOptions, ShardFeed};
use crate::text::vocab::Vocab;
use crate::transport::{ArtifactStore, Transport};
use crate::util::config::ExperimentConfig;
use crate::util::env;
use crate::util::json;
use crate::util::logging::Timer;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, ExitStatus};
use std::sync::{Arc, Mutex};
use std::time::Instant;

// ---------------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------------

/// What one `dw2v train-worker` invocation trains and where it puts it.
pub struct WorkerSpec {
    /// directory of `shard_*.bin` + `vocab.tsv`
    pub shard_dir: PathBuf,
    /// sub-model index in `0..100/r`
    pub submodel: usize,
    /// artifact output path
    pub out: PathBuf,
    /// when set, talk to a `dw2v shard-server` at `HOST:PORT` instead of
    /// the local filesystem (shards are mirrored into a temp cache,
    /// artifacts/beacons/journals are uploaded)
    pub connect: Option<String>,
}

// Re-exported from the transport layer, where run-dir naming now lives;
// kept here so existing `procs::checkpoint_path` etc. callers hold.
pub use crate::transport::fs::{checkpoint_path, clean_artifact_dir, collect_artifact};

/// Environment variable that switches workers from the up-front
/// [`ShardFileSource`] snapshot to the manifest-driven [`ShardFeed`]
/// (ingest/training overlap). The overlap driver sets it on the whole
/// fleet through [`ProcsOptions::extra_env`]; see the module docs.
pub const FEED_ENV: &str = env::FEED;

/// The `extra_env` entry that enables feed mode.
pub fn feed_env_pair() -> (String, String) {
    (FEED_ENV.to_string(), "1".to_string())
}

/// The sentence stream a worker trains from: the complete-directory
/// snapshot, or the manifest-driven feed that follows a still-growing
/// directory (feed mode). One enum so the epoch loop has a single code
/// path — both yield the same `(global index, sentence)` stream over a
/// finished directory.
enum WorkerSource {
    Snapshot(ShardFileSource),
    Feed(ShardFeed),
}

impl WorkerSource {
    fn take_error(&self) -> Option<String> {
        match self {
            WorkerSource::Snapshot(s) => s.take_error(),
            WorkerSource::Feed(f) => f.take_error(),
        }
    }

    fn describe(&self) -> String {
        match self {
            WorkerSource::Snapshot(s) => format!("{} shard files", s.num_files()),
            WorkerSource::Feed(_) => "a growing shard dir (feed mode)".to_string(),
        }
    }
}

impl RoundSource for WorkerSource {
    type Item = (usize, Vec<u32>);

    fn shard(
        &self,
        round: usize,
        shard: usize,
        num_shards: usize,
    ) -> Box<dyn Iterator<Item = (usize, Vec<u32>)> + '_> {
        match self {
            WorkerSource::Snapshot(s) => s.shard(round, shard, num_shards),
            WorkerSource::Feed(f) => f.shard(round, shard, num_shards),
        }
    }
}

/// The reducer a worker actually runs: the plain [`TrainReducer`] wrapped
/// with the supervision duties — beacon publication on progress and the
/// fault-injection trigger points. Kept out of `TrainReducer` itself so
/// the in-process leader path pays nothing for supervision.
struct WorkerReducer<'b, B: Backend> {
    inner: TrainReducer<'b, B>,
    /// shared with the feed's wait hook in feed mode (the mapper thread
    /// beacons `waiting` while blocked on an unpublished shard, the
    /// reducer thread beacons `train` progress), hence the mutex
    beacon: Arc<Mutex<BeaconWriter>>,
    faults: ArmedFaults,
}

impl<'b, B: Backend> Reducer<(u64, Vec<u32>)> for WorkerReducer<'b, B> {
    fn reduce(&mut self, (sid, sentence): (u64, Vec<u32>)) {
        let epoch = (sid >> SID_INDEX_BITS) as usize;
        self.inner.reduce((sid, sentence));
        self.faults.on_progress(self.inner.trainer.pairs_emitted());
        self.beacon.lock().unwrap().maybe_write(
            "train",
            epoch,
            self.inner.trainer.sentences_received,
            self.inner.trainer.pairs_emitted(),
        );
    }

    fn end_round(&mut self, round: usize) {
        self.inner.end_round(round);
        // force a beacon at the barrier: a worker between epochs must not
        // look stalled just because no sentence arrived within the interval
        self.beacon.lock().unwrap().write_now(
            "train",
            round + 1,
            self.inner.trainer.sentences_received,
            self.inner.trainer.pairs_emitted(),
        );
    }
}

/// Validate a checkpoint found on disk against this run's identity.
/// Anything that doesn't match — other run, other sub-model, stale
/// corpus, already-finished — is an error; the caller discards the file
/// and trains from scratch rather than resuming into the wrong stream.
fn validate_checkpoint(
    ck: &CheckpointArtifact,
    cfg: &ExperimentConfig,
    spec: &WorkerSpec,
    num_submodels: usize,
    trainer_seed: u64,
    total_sentences: usize,
    vocab_len: usize,
) -> Result<(), String> {
    let m = &ck.meta;
    if m.submodel != spec.submodel
        || m.num_submodels != num_submodels
        || m.root_seed != cfg.seed
        || m.trainer_seed != trainer_seed
        || m.strategy != cfg.strategy.name()
        || m.rate_percent != cfg.rate_percent
        || m.epochs != cfg.epochs
    {
        return Err(format!(
            "belongs to a different run (submodel {} of {}, root seed {}, \
             strategy {}, rate {}%, {} epochs)",
            m.submodel, m.num_submodels, m.root_seed, m.strategy, m.rate_percent, m.epochs
        ));
    }
    if m.total_sentences != total_sentences || m.vocab != vocab_len {
        return Err(format!(
            "corpus changed since checkpoint ({} sentences / vocab {} then, \
             {} / {} now)",
            m.total_sentences, m.vocab, total_sentences, vocab_len
        ));
    }
    if m.epochs_done == 0 || m.epochs_done >= cfg.epochs {
        return Err(format!(
            "claims {} of {} epochs done — nothing to resume",
            m.epochs_done, cfg.epochs
        ));
    }
    Ok(())
}

/// Snapshot the trainer at the epoch boundary just crossed and publish
/// it atomically as `submodel_<s>.ckpt` through the transport's
/// [`ArtifactStore`], replacing any older checkpoint.
fn write_checkpoint<B: Backend>(
    cfg: &ExperimentConfig,
    spec: &WorkerSpec,
    artifacts: &dyn ArtifactStore,
    num_submodels: usize,
    trainer_seed: u64,
    total_sentences: usize,
    epochs_done: usize,
    red: &WorkerReducer<'_, B>,
) -> Result<(), String> {
    let snap = red
        .inner
        .trainer
        .snapshot()
        .map_err(|e| format!("checkpoint snapshot: {e}"))?;
    let meta = CheckpointMeta {
        submodel: spec.submodel,
        num_submodels,
        root_seed: cfg.seed,
        trainer_seed,
        strategy: cfg.strategy.name().to_string(),
        rate_percent: cfg.rate_percent,
        epochs: cfg.epochs,
        epochs_done,
        total_sentences,
        vocab: snap.seen_counts.len(),
        dispatched_pairs: snap.dispatched_pairs,
        pairs_emitted: snap.pairs_emitted,
        sentences_received: snap.sentences_received,
        dispatches: snap.dispatches,
        loss_sum: snap.metrics.loss_sum,
        examples: snap.metrics.examples,
        micro_steps: snap.metrics.micro_steps,
        epoch_loss: red.inner.epoch_mean_loss.clone(),
    };
    // the packed payload rides the embedding body format; rows = 2V+2
    let rows = snap.packed.len() / cfg.dim.max(1);
    let ck = CheckpointArtifact {
        meta,
        seen_counts: snap.seen_counts,
        packed: Embedding {
            vocab: rows,
            dim: cfg.dim,
            data: snap.packed,
            present: vec![true; rows],
        },
    };
    artifacts.save_checkpoint(spec.submodel, &ck)
}

/// Train one sub-model in this process — the whole worker protocol.
/// Streams the corpus from `spec.shard_dir`, trains sub-model
/// `spec.submodel` (resuming from a valid `submodel_<s>.ckpt` when one
/// exists), publishes a beacon throughout, and atomically publishes a
/// [`SubModelArtifact`] at `spec.out`. Any error (unreadable shards, bad
/// index, backend failure) is returned, which the CLI turns into a
/// non-zero exit the coordinator records as a failed worker.
pub fn run_worker(cfg: &ExperimentConfig, spec: &WorkerSpec) -> Result<(), String> {
    // stamp every log line of this process with its sub-model identity —
    // a supervised fleet interleaves worker stderr on one terminal
    crate::util::logging::set_role(&format!("worker s={}", spec.submodel));
    if let Some(ms) = env::worker_startup_sleep_ms()? {
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
    // a malformed fault spec is a startup error, never a silent no-op —
    // a chaos test with a typo'd spec must fail loudly, not pass vacuously
    let fault_spec = match env::fault_spec() {
        Some(text) => {
            FaultSpec::parse(&text, spec.submodel).map_err(|e| format!("{}: {e}", env::FAULT))?
        }
        None => FaultSpec::default(),
    };
    let feed_mode = env::feed_mode()?;
    let beacon_interval = env::beacon_interval_ms()?;
    // everything this worker exchanges with its coordinator — shards in,
    // beacons/checkpoints/artifacts/journal events out — goes through one
    // transport: the run dir next to `--out`, or a shard server when
    // `--connect` is set
    let transport = match &spec.connect {
        Some(addr) => Transport::connect(addr, spec.submodel, feed_mode)?,
        None => Transport::fs_worker(&spec.shard_dir, &spec.out),
    };
    let shard_dir = transport.shards.local_dir().to_path_buf();
    let beacon = Arc::new(Mutex::new(BeaconWriter::new(
        Arc::clone(&transport.control),
        spec.submodel,
        beacon_interval,
    )));
    beacon.lock().unwrap().write_now("start", 0, 0, 0);
    // per-worker event journal next to the artifacts; a respawned
    // incarnation appends to the same file (for remote workers the server
    // appends on their behalf), so the run's full timeline — including
    // the pre-crash epochs — survives in one place
    let journal = transport
        .control
        .journal(&format!("worker_{}", spec.submodel));
    journal.event(
        "worker_start",
        vec![("submodel", json::inum(spec.submodel))],
    );
    let faults = ArmedFaults::new(fault_spec, Arc::clone(&transport.control), spec.submodel);

    // feed mode: ingest may still be running — its schedule block (and
    // vocab.tsv, written just before it) is the readiness signal
    let feed_opts = FeedOptions::default();
    let schedule = if feed_mode {
        let hb = Arc::clone(&beacon);
        let (_, sched) = feed::wait_for_schedule(&shard_dir, &feed_opts, move || {
            hb.lock().unwrap().maybe_write("waiting", 0, 0, 0);
        })?;
        Some(sched)
    } else {
        None
    };

    let vocab_text = transport.shards.vocab_text()?;
    let vocab = Vocab::from_tsv(&vocab_text)?;
    if vocab.is_empty() {
        return Err(format!(
            "{} holds an empty vocabulary",
            shard_dir.join("vocab.tsv").display()
        ));
    }
    let scfg = leader::sgns_config(cfg);
    let (source, total) = match &schedule {
        Some(sched) => {
            // the schedule was computed under one (window, subsample_t);
            // training under any other would silently desynchronize the
            // lr denominator from the actual pair stream
            if sched.window != scfg.window
                || sched.subsample_t.to_bits() != scfg.subsample_t.to_bits()
            {
                return Err(format!(
                    "manifest schedule was computed for window {} / subsample_t {:e} but \
                     this run uses window {} / subsample_t {:e} — re-ingest with the \
                     matching config",
                    sched.window, sched.subsample_t, scfg.window, scfg.subsample_t
                ));
            }
            let mut f = ShardFeed::open(&shard_dir, feed_opts)?;
            let hb = Arc::clone(&beacon);
            f.set_wait_hook(Box::new(move |awaiting, published| {
                // seq bumps per write, so even a long wait on one shard
                // keeps changing bytes — liveness for the stall detector
                hb.lock()
                    .unwrap()
                    .maybe_write("waiting", 0, awaiting as u64, published as u64);
            }));
            (WorkerSource::Feed(f), sched.total_sentences as usize)
        }
        None => {
            let s = ShardFileSource::open(&shard_dir)?;
            let total = s.total_sentences();
            (WorkerSource::Snapshot(s), total)
        }
    };
    if total == 0 {
        return Err(format!(
            "shards in {} hold no sentences",
            shard_dir.display()
        ));
    }

    let divider = Arc::new(leader::run_divider(cfg, total)?);
    if spec.submodel >= divider.num_submodels {
        return Err(format!(
            "sub-model index {} out of range: rate {}% implies {} sub-models",
            spec.submodel, cfg.rate_percent, divider.num_submodels
        ));
    }

    // lr-schedule denominator. Snapshot mode streams the finished shards
    // once, exactly as the in-process leader does over the in-memory
    // corpus (same sentence order ⇒ bitwise-identical sum). Feed mode
    // takes the value the overlapped ingest computed over the identically
    // encoded stream and published bits-exact in the manifest — running
    // our own pass here would block on every shard, defeating the overlap.
    let per_epoch_pairs = match &schedule {
        Some(sched) => sched.per_epoch_pairs,
        None => {
            let est_started = Instant::now();
            let mut est = PairEstimator::new(&vocab, &scfg);
            let mut seen = 0u64;
            for (_, sentence) in source.shard(0, 0, 1) {
                est.add_sentence(&sentence);
                seen += 1;
                if seen % 4096 == 0 {
                    beacon.lock().unwrap().maybe_write("estimate", 0, seen, 0);
                }
            }
            if let Some(e) = source.take_error() {
                return Err(format!("estimation pass failed: {e}"));
            }
            journal.event(
                "estimate_done",
                vec![
                    ("submodel", json::inum(spec.submodel)),
                    ("secs", json::num(est_started.elapsed().as_secs_f64())),
                    ("sentences", u64s(seen)),
                ],
            );
            est.per_epoch()
        }
    };
    let expected_pairs = leader::submodel_expected_pairs(cfg, per_epoch_pairs, &divider, total);
    let trainer_seed = leader::submodel_seed(cfg.seed, spec.submodel);

    let backend = load_backend(cfg, vocab.len())?;
    info!(
        "worker {}: {} sentences from {}, {} epochs, expected ~{} pairs, backend {}",
        spec.submodel,
        total,
        source.describe(),
        cfg.epochs,
        expected_pairs,
        backend.name()
    );

    let mut trainer = SubModelTrainer::new(&backend, &vocab, &scfg, expected_pairs, trainer_seed)?;

    // resume: a valid checkpoint left by a previous incarnation of this
    // worker restores the trainer and skips the epochs already done
    let ckpt_desc = transport.artifacts.checkpoint_desc(spec.submodel);
    let mut start_epoch = 0usize;
    let mut resumed_loss: Vec<f64> = Vec::new();
    let mut resume_prev = Metrics::default();
    if let Some(found) = transport.artifacts.load_checkpoint(spec.submodel) {
        let loaded = found.and_then(|ck| {
            validate_checkpoint(
                &ck,
                cfg,
                spec,
                divider.num_submodels,
                trainer_seed,
                total,
                vocab.len(),
            )
            .map(|()| ck)
        });
        match loaded {
            Ok(ck) => {
                let snap = TrainerSnapshot {
                    packed: ck.packed.data,
                    seen_counts: ck.seen_counts,
                    dispatched_pairs: ck.meta.dispatched_pairs,
                    pairs_emitted: ck.meta.pairs_emitted,
                    sentences_received: ck.meta.sentences_received,
                    dispatches: ck.meta.dispatches,
                    metrics: Metrics {
                        loss_sum: ck.meta.loss_sum,
                        examples: ck.meta.examples,
                        micro_steps: ck.meta.micro_steps,
                    },
                };
                trainer
                    .restore(&snap)
                    .map_err(|e| format!("restore checkpoint {ckpt_desc}: {e}"))?;
                start_epoch = ck.meta.epochs_done;
                resumed_loss = ck.meta.epoch_loss;
                resume_prev = snap.metrics;
                info!(
                    "worker {}: resuming from {ckpt_desc} at epoch {start_epoch}/{} \
                     ({} pairs dispatched)",
                    spec.submodel,
                    cfg.epochs,
                    snap.dispatched_pairs
                );
            }
            Err(why) => {
                // invalid ≠ fatal: discard and train from scratch
                info!(
                    "worker {}: ignoring checkpoint {ckpt_desc} — {why}",
                    spec.submodel
                );
                transport.artifacts.remove_checkpoint(spec.submodel);
            }
        }
    }

    let mut inner = TrainReducer::new(trainer);
    inner.resume_loss_baseline(resumed_loss, resume_prev);
    let mut reducers = vec![WorkerReducer {
        inner,
        beacon,
        faults,
    }];
    let timer = Timer::start("worker train");
    let mr = MapReduce {
        num_mappers: cfg.mappers.max(1),
        queue_capacity: cfg.queue_capacity,
    };
    let submodel = spec.submodel;
    // one run_range call per epoch (≡ one run(n) call: MapReduce builds
    // fresh channels and threads per round either way) so the trainer can
    // be checkpointed at every epoch barrier
    for epoch in start_epoch..cfg.epochs {
        reducers[0].faults.maybe_stall(epoch);
        let epoch_started = Instant::now();
        let pairs_before = reducers[0].inner.trainer.pairs_emitted();
        mr.run_range(
            epoch..epoch + 1,
            &source,
            |ep, _shard| SubModelFilter::new(Arc::clone(&divider), ep, submodel),
            &mut reducers,
        );
        if let Some(e) = source.take_error() {
            return Err(format!("shard streaming failed mid-train: {e}"));
        }
        if let Some(e) = reducers[0].inner.error.take() {
            return Err(format!("trainer failed: {e}"));
        }
        let epoch_secs = epoch_started.elapsed().as_secs_f64();
        let epoch_pairs = reducers[0].inner.trainer.pairs_emitted() - pairs_before;
        journal.event(
            "epoch_done",
            vec![
                ("submodel", json::inum(spec.submodel)),
                ("epoch", json::inum(epoch)),
                ("secs", json::num(epoch_secs)),
                ("pairs", u64s(epoch_pairs)),
                (
                    "pairs_per_s",
                    json::num(if epoch_secs > 0.0 {
                        epoch_pairs as f64 / epoch_secs
                    } else {
                        0.0
                    }),
                ),
            ],
        );
        if epoch + 1 < cfg.epochs {
            let ck_started = Instant::now();
            write_checkpoint(
                cfg,
                spec,
                transport.artifacts.as_ref(),
                divider.num_submodels,
                trainer_seed,
                total,
                epoch + 1,
                &reducers[0],
            )?;
            journal.event(
                "checkpoint_written",
                vec![
                    ("submodel", json::inum(spec.submodel)),
                    ("epoch", json::inum(epoch + 1)),
                    ("secs", json::num(ck_started.elapsed().as_secs_f64())),
                ],
            );
        }
    }
    let train_secs = timer.stop_quiet();

    // feed mode: the feed drained to the manifest's completion mark every
    // epoch — cross-check the final manifest against the schedule it was
    // trained under, then publish the feed statistics the overlap e2e and
    // benches read (`shards_at_train_start < shards_final` is the proof
    // that training really did start before ingest finished)
    if let WorkerSource::Feed(f) = &source {
        let sched = schedule.as_ref().expect("feed mode implies a schedule");
        let man = transport
            .shards
            .manifest()?
            .ok_or_else(|| format!("{} lost its manifest mid-run", shard_dir.display()))?;
        if !man.complete || man.total_sentences() != sched.total_sentences {
            return Err(format!(
                "{}: manifest ended {} with {} sentences but the schedule promised {} — \
                 ingest died or the dir changed mid-run",
                shard_dir.display(),
                if man.complete { "complete" } else { "incomplete" },
                man.total_sentences(),
                sched.total_sentences
            ));
        }
        let st = f.stats();
        journal.event(
            "feed_wait",
            vec![
                ("submodel", json::inum(spec.submodel)),
                ("waits", u64s(st.waits)),
                ("wait_secs", json::num(st.wait_secs)),
                ("shards_at_open", json::inum(st.shards_at_open)),
            ],
        );
        let body = json::obj(vec![
            ("submodel", json::inum(spec.submodel)),
            ("shards_at_train_start", json::inum(st.shards_at_open)),
            ("shards_final", json::inum(man.num_shards())),
            ("waits", json::s(&st.waits.to_string())),
            ("wait_secs", json::num(st.wait_secs)),
        ])
        .to_string_pretty();
        transport.control.publish_feedstat(spec.submodel, &body)?;
    }

    let worker_red = reducers.pop().expect("one reducer");
    let corrupt = worker_red.faults.corrupt_artifact();
    let beacon = worker_red.beacon;
    let red = worker_red.inner;
    if let Some(e) = red.error {
        return Err(format!("trainer failed: {e}"));
    }

    let pairs = red.trainer.pairs_emitted();
    let epoch_loss = red.epoch_mean_loss.clone();
    let sentences = red.trainer.sentences_received;
    let embedding = red.trainer.into_embedding(cfg.submodel_min_count())?;
    let artifact = SubModelArtifact {
        meta: ArtifactMeta {
            submodel: spec.submodel,
            num_submodels: divider.num_submodels,
            root_seed: cfg.seed,
            trainer_seed,
            strategy: cfg.strategy.name().to_string(),
            rate_percent: cfg.rate_percent,
            epochs: cfg.epochs,
            pairs,
            epoch_loss,
        },
        embedding,
    };
    // the store publishes write-to-temp + rename (with the fault
    // injection's truncation applied to the temp file when `corrupt`),
    // so the coordinator can never observe a partial artifact
    transport
        .artifacts
        .publish_artifact(spec.submodel, &artifact, corrupt)?;
    // the artifact supersedes the checkpoint; leaving it behind would only
    // confuse the stale-file cleanup of the next run
    transport.artifacts.remove_checkpoint(spec.submodel);
    journal.event(
        "artifact_published",
        vec![
            ("submodel", json::inum(spec.submodel)),
            ("pairs", u64s(pairs)),
        ],
    );
    journal.event(
        "worker_done",
        vec![
            ("submodel", json::inum(spec.submodel)),
            ("secs", json::num(train_secs)),
        ],
    );
    beacon.lock().unwrap().write_now("done", cfg.epochs, sentences, pairs);
    info!(
        "worker {}: done in {train_secs:.2}s — {sentences} sentences, {pairs} pairs, artifact {}",
        spec.submodel,
        spec.out.display()
    );
    // remote workers drop their local shard cache; the fs transport's
    // cleanup is a no-op
    transport.shards.cleanup();
    Ok(())
}

// ---------------------------------------------------------------------------
// coordinator side
// ---------------------------------------------------------------------------

/// How the coordinator spawns its workers.
pub struct ProcsOptions {
    /// the `dw2v` binary to execute (see [`find_worker_exe`])
    pub worker_exe: PathBuf,
    /// directory of `shard_*.bin` + `vocab.tsv` the workers stream
    pub shard_dir: PathBuf,
    /// where worker artifacts (and the run's `config.json`) land
    pub out_dir: PathBuf,
    /// extra environment for the workers (test hooks; empty in production)
    pub extra_env: Vec<(String, String)>,
    /// when set, spawned workers get `--connect HOST:PORT` and fetch
    /// shards from (and upload artifacts to) a `dw2v shard-server`
    /// instead of touching `shard_dir`/`out_dir` themselves. The server
    /// mirrors every upload into its own run dir, so the supervisor's
    /// beacon polling and artifact collection work unchanged.
    pub connect: Option<String>,
}

/// Why a worker produced no usable sub-model.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkerFate {
    /// exited 0 and its artifact loaded and matched the run config
    Completed,
    /// crashed, was killed, stalled, exited non-zero, or published a bad
    /// artifact
    Failed(String),
}

impl std::fmt::Display for WorkerFate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkerFate::Completed => write!(f, "ok"),
            WorkerFate::Failed(why) => write!(f, "FAILED — {why}"),
        }
    }
}

/// One worker's result as the coordinator saw it.
pub struct WorkerOutcome {
    pub submodel: usize,
    /// wall-clock from spawn to exit
    pub secs: f64,
    pub fate: WorkerFate,
    /// present iff `fate` is `Completed`
    pub artifact: Option<SubModelArtifact>,
}

impl WorkerOutcome {
    pub fn survived(&self) -> bool {
        self.artifact.is_some()
    }
}

struct WorkerChild {
    submodel: usize,
    child: Child,
    out: PathBuf,
    /// `Ok(status)` once the child was reaped, `Err(why)` if it became
    /// unwaitable; plus seconds since pool start
    finished: Option<(Result<ExitStatus, String>, f64)>,
}

/// Live handle on a set of spawned workers. Obtained from
/// [`spawn_workers`]; [`Self::wait`] monitors them to completion. The
/// split (rather than one blocking call) exists so callers — the
/// kill-a-worker e2e above all — can reach the children (e.g.
/// [`Self::pid`]) while they run.
pub struct WorkerPool {
    children: Vec<WorkerChild>,
    started: Instant,
    root_seed: u64,
    num_submodels: usize,
}

pub(crate) fn describe_status(status: &ExitStatus) -> String {
    if status.success() {
        return "ok".to_string();
    }
    if let Some(code) = status.code() {
        return format!("exit code {code}");
    }
    #[cfg(unix)]
    {
        use std::os::unix::process::ExitStatusExt;
        if let Some(sig) = status.signal() {
            return format!("killed by signal {sig}");
        }
    }
    "terminated abnormally".to_string()
}

/// Everything a coordinator does before the first spawn: validate the
/// rate and the shard dir, create `out_dir`, sweep stale run files, and
/// write the run's `config.json`. Returns the sub-model count and the
/// config path to hand to [`spawn_one_worker`].
///
/// When `opts.extra_env` carries [`feed_env_pair`] (overlap), the shard
/// dir is validated through its manifest instead of a
/// [`ShardFileSource`] probe — the shards are still being written, so
/// listing them would both race the ingest and reject the run for
/// having "too few" files. The manifest's schedule block is required:
/// the overlap driver only spawns after [`feed::wait_for_schedule`].
pub fn prepare_run(
    cfg: &ExperimentConfig,
    opts: &ProcsOptions,
) -> Result<(usize, PathBuf), String> {
    // validate before num_submodels(): a rate of 0 would saturate the
    // count to usize::MAX and the spawn loop would fork-bomb the host
    // long before any worker's Divider::new could reject it
    crate::util::config::validate_rate_percent(cfg.rate_percent)?;
    let n = cfg.num_submodels();
    // the coordinator's own view of the run dir is always the local
    // filesystem — with `--connect`, only the *workers* go over TCP, and
    // the server mirrors their uploads back into this same dir
    let transport = Transport::fs(&opts.shard_dir, &opts.out_dir);
    if !transport.shards.has_vocab() {
        return Err(format!(
            "{} has no vocab.tsv — persist a corpus first (gen-corpus, or --text with --shard-dir)",
            opts.shard_dir.display()
        ));
    }
    let feed_mode = opts
        .extra_env
        .iter()
        .any(|(k, v)| k == FEED_ENV && v.trim() == "1");
    let corpus_desc = if feed_mode {
        match transport.shards.manifest()? {
            Some(m) if m.schedule.is_some() => format!(
                "a growing shard dir ({} shards published so far)",
                m.num_shards()
            ),
            _ => {
                return Err(format!(
                    "{}: feed mode ({FEED_ENV}=1) requires a manifest with a schedule \
                     block — wait for the overlapped ingest's schedule before spawning",
                    opts.shard_dir.display()
                ))
            }
        }
    } else {
        let swept = transport.shards.sweep_torn()?;
        if swept > 0 {
            info!(
                "coordinator: removed {swept} torn .tmp files from {}",
                opts.shard_dir.display()
            );
        }
        // fail fast on an unreadable corpus before paying n process spawns
        let probe = ShardFileSource::open(&opts.shard_dir)?;
        format!(
            "{} shard files ({} sentences)",
            probe.num_files(),
            probe.total_sentences()
        )
    };
    let removed = transport.artifacts.prepare_out_dir()?;
    if removed > 0 {
        info!(
            "coordinator: removed {removed} stale run files from {}",
            opts.out_dir.display()
        );
    }
    // the seed is re-encoded as a decimal string: u64s above 2^53 don't
    // survive a JSON f64 round trip, and `apply` parses strings exactly
    let mut config_json = cfg.to_json();
    if let crate::util::json::Json::Obj(o) = &mut config_json {
        o.insert(
            "seed".to_string(),
            crate::util::json::Json::Str(cfg.seed.to_string()),
        );
    }
    let config_path = transport
        .artifacts
        .write_config(&config_json.to_string_pretty())?;
    info!(
        "coordinator: spawning {n} workers over {corpus_desc}, exe {}",
        opts.worker_exe.display()
    );
    Ok((n, config_path))
}

/// Spawn one `train-worker` process. `extra_env` is appended after
/// `opts.extra_env` (the supervisor uses it for the beacon interval).
pub fn spawn_one_worker(
    cfg: &ExperimentConfig,
    opts: &ProcsOptions,
    config_path: &Path,
    submodel: usize,
    extra_env: &[(String, String)],
) -> Result<Child, String> {
    let out = opts.out_dir.join(format!("submodel_{submodel}.dwsm"));
    let mut cmd = Command::new(&opts.worker_exe);
    cmd.arg("train-worker")
        .arg("--config")
        .arg(config_path)
        .arg("--seed")
        .arg(cfg.seed.to_string())
        .arg("--shard-dir")
        .arg(&opts.shard_dir)
        .arg("--submodel")
        .arg(submodel.to_string())
        .arg("--out")
        .arg(&out);
    if let Some(addr) = &opts.connect {
        cmd.arg("--connect").arg(addr);
    }
    for (k, v) in opts.extra_env.iter().chain(extra_env) {
        cmd.env(k, v);
    }
    cmd.spawn().map_err(|e| {
        format!(
            "spawn worker {submodel} ({}): {e}",
            opts.worker_exe.display()
        )
    })
}

/// Spawn one `train-worker` process per sub-model. The experiment config
/// is passed as a `config.json` in `out_dir` plus an explicit `--seed`
/// override (u64 seeds don't survive a JSON f64 round trip above 2^53).
pub fn spawn_workers(cfg: &ExperimentConfig, opts: &ProcsOptions) -> Result<WorkerPool, String> {
    let (n, config_path) = prepare_run(cfg, opts)?;
    let mut children = Vec::with_capacity(n);
    let started = Instant::now();
    for s in 0..n {
        let child = match spawn_one_worker(cfg, opts, &config_path, s, &[]) {
            Ok(c) => c,
            Err(e) => {
                // don't leak the workers already launched: left alone they
                // would train the whole run and drop artifacts into
                // out_dir that a later identically-configured run could
                // mistake for its own
                for mut wc in children {
                    let _ = wc.child.kill();
                    let _ = wc.child.wait();
                }
                return Err(e);
            }
        };
        children.push(WorkerChild {
            submodel: s,
            child,
            out: opts.out_dir.join(format!("submodel_{s}.dwsm")),
            finished: None,
        });
    }
    Ok(WorkerPool {
        children,
        started,
        root_seed: cfg.seed,
        num_submodels: n,
    })
}

impl WorkerPool {
    /// OS pid of a still-tracked worker.
    pub fn pid(&self, submodel: usize) -> Option<u32> {
        self.children
            .iter()
            .find(|c| c.submodel == submodel)
            .map(|c| c.child.id())
    }

    /// Monitor the workers to completion: poll every few milliseconds,
    /// log each exit as it happens, then validate and collect the
    /// artifacts of the workers that exited cleanly. Returns the
    /// per-worker outcomes plus the wall-clock of the whole train phase.
    pub fn wait(mut self) -> (Vec<WorkerOutcome>, f64) {
        let mut pending = self.children.len();
        while pending > 0 {
            pending = 0;
            for wc in self.children.iter_mut() {
                if wc.finished.is_some() {
                    continue;
                }
                match wc.child.try_wait() {
                    Ok(Some(status)) => {
                        let secs = self.started.elapsed().as_secs_f64();
                        info!(
                            "coordinator: worker {} exited after {secs:.2}s ({})",
                            wc.submodel,
                            describe_status(&status)
                        );
                        wc.finished = Some((Ok(status), secs));
                    }
                    Ok(None) => pending += 1,
                    Err(e) => {
                        // an unwaitable child counts as a failed worker
                        let secs = self.started.elapsed().as_secs_f64();
                        wc.finished = Some((Err(format!("wait failed: {e}")), secs));
                    }
                }
            }
            if pending > 0 {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
        let train_secs = self.started.elapsed().as_secs_f64();
        let (root_seed, n) = (self.root_seed, self.num_submodels);
        let outcomes = self
            .children
            .into_iter()
            .map(|wc| {
                let (status, secs) = wc.finished.expect("all children waited");
                let clean = matches!(&status, Ok(st) if st.success());
                let (fate, artifact) = if !clean {
                    let why = match &status {
                        Ok(st) => describe_status(st),
                        Err(e) => e.clone(),
                    };
                    (WorkerFate::Failed(why), None)
                } else {
                    match collect_artifact(&wc.out, wc.submodel, root_seed, n) {
                        Ok(a) => (WorkerFate::Completed, Some(a)),
                        Err(why) => (WorkerFate::Failed(why), None),
                    }
                };
                WorkerOutcome {
                    submodel: wc.submodel,
                    secs,
                    fate,
                    artifact,
                }
            })
            .collect();
        (outcomes, train_secs)
    }
}

/// Result of a full multi-process run.
pub struct ProcsReport {
    /// per-worker fates, in sub-model order — failures included
    pub outcomes: Vec<WorkerOutcome>,
    /// wall-clock from first spawn to last worker exit
    pub train_secs: f64,
    /// the shared merge + eval tail over the surviving sub-models
    pub tail: leader::MergeEvalOutput,
}

impl ProcsReport {
    pub fn survivors(&self) -> usize {
        self.outcomes.iter().filter(|o| o.survived()).count()
    }

    pub fn failures(&self) -> impl Iterator<Item = &WorkerOutcome> {
        self.outcomes.iter().filter(|o| !o.survived())
    }
}

/// The shared merge + eval tail over whatever workers survived. Errors
/// only when **no** worker survived — any smaller set of failures
/// degrades gracefully into a merge over the survivors (the paper's
/// robustness claim, promoted to sub-model granularity). The surviving
/// artifacts' embeddings are moved out for the merge — cloning them
/// would double coordinator peak memory (sub-models can be GBs) — and
/// put back afterwards so the outcomes stay whole.
pub(crate) fn merge_survivor_tail(
    cfg: &ExperimentConfig,
    suite: &[Benchmark],
    outcomes: &mut [WorkerOutcome],
) -> Result<leader::MergeEvalOutput, String> {
    let submodels: Vec<Embedding> = outcomes
        .iter_mut()
        .filter_map(|o| o.artifact.as_mut())
        .map(|a| std::mem::replace(&mut a.embedding, Embedding::zeros(0, 1)))
        .collect();
    if submodels.is_empty() {
        let detail: Vec<String> = outcomes
            .iter()
            .map(|o| format!("worker {}: {}", o.submodel, o.fate))
            .collect();
        return Err(format!(
            "all {} workers failed — nothing to merge:\n  {}",
            outcomes.len(),
            detail.join("\n  ")
        ));
    }
    let survivors = submodels.len();
    if survivors < outcomes.len() {
        info!(
            "coordinator: merging {survivors}/{} sub-models (the rest failed)",
            outcomes.len()
        );
    }
    let tail = leader::merge_and_eval(cfg, &submodels, suite);
    let mut returned = submodels.into_iter();
    for a in outcomes.iter_mut().filter_map(|o| o.artifact.as_mut()) {
        a.embedding = returned.next().expect("one embedding per survivor");
    }
    Ok(tail)
}

/// The full multi-process pipeline without supervision: spawn `100/r`
/// workers, wait for them, merge + eval whatever came back. The
/// supervised variant is [`super::supervisor::run_supervised`].
pub fn run_multiprocess(
    cfg: &ExperimentConfig,
    suite: &[Benchmark],
    opts: &ProcsOptions,
) -> Result<ProcsReport, String> {
    let pool = spawn_workers(cfg, opts)?;
    let (mut outcomes, train_secs) = pool.wait();
    let tail = merge_survivor_tail(cfg, suite, &mut outcomes)?;
    Ok(ProcsReport {
        outcomes,
        train_secs,
        tail,
    })
}

/// Locate the `dw2v` binary to use as the worker executable:
/// `DW2V_WORKER_EXE` if set, the current executable when it *is* `dw2v`
/// (the CLI case), else a `dw2v` sibling of the current executable or of
/// its parent directory (the `target/<profile>/examples/…` case).
pub fn find_worker_exe() -> Result<PathBuf, String> {
    if let Some(p) = env::worker_exe() {
        let p = PathBuf::from(p);
        if p.is_file() {
            return Ok(p);
        }
        return Err(format!("DW2V_WORKER_EXE={} does not exist", p.display()));
    }
    let me = std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?;
    let name = format!("dw2v{}", std::env::consts::EXE_SUFFIX);
    if me.file_name().and_then(|n| n.to_str()) == Some(name.as_str()) {
        return Ok(me);
    }
    for dir in [me.parent(), me.parent().and_then(|d| d.parent())]
        .into_iter()
        .flatten()
    {
        let candidate = dir.join(&name);
        if candidate.is_file() {
            return Ok(candidate);
        }
    }
    Err(format!(
        "cannot locate the dw2v binary next to {} — build it (`cargo build --bin dw2v`) \
         or set DW2V_WORKER_EXE",
        me.display()
    ))
}

