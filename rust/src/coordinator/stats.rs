//! Distributional statistics: the empirical evidence behind Hypothesis 1.
//!
//! Figure 1 of the paper plots the KL divergence of each sub-corpus's
//! unigram and bigram distributions from the full corpus's, comparing
//! RandomSampling against EqualPartitioning. This module computes exactly
//! those quantities, plus the vocabulary-coverage statistics quoted in
//! §3.1 (common-vocabulary fraction across sub-corpora).

use crate::text::corpus::Corpus;
use std::collections::HashMap;

/// Empirical unigram + (adjacent) bigram distribution of a corpus sample.
#[derive(Clone, Debug, Default)]
pub struct DistStats {
    pub unigram: HashMap<u32, u64>,
    pub bigram: HashMap<(u32, u32), u64>,
    pub tokens: u64,
    pub bigrams: u64,
}

impl DistStats {
    pub fn add_sentence(&mut self, sentence: &[u32]) {
        for &w in sentence {
            *self.unigram.entry(w).or_insert(0) += 1;
            self.tokens += 1;
        }
        for pair in sentence.windows(2) {
            *self.bigram.entry((pair[0], pair[1])).or_insert(0) += 1;
            self.bigrams += 1;
        }
    }

    pub fn from_sentences<'a>(sentences: impl Iterator<Item = &'a Vec<u32>>) -> Self {
        let mut s = Self::default();
        for sent in sentences {
            s.add_sentence(sent);
        }
        s
    }

    pub fn from_corpus(corpus: &Corpus) -> Self {
        Self::from_sentences(corpus.sentences.iter())
    }

    /// Vocabulary (distinct unigrams) of this sample.
    pub fn vocab_set(&self) -> std::collections::HashSet<u32> {
        self.unigram.keys().copied().collect()
    }
}

/// KL(P‖Q) over the *support of P* with add-α smoothing on Q (a sub-corpus
/// can miss words; the full corpus never misses sub-corpus words, but
/// smoothing keeps the estimator finite in both directions).
fn kl(
    p_counts: impl Iterator<Item = (u64, u64)> + Clone,
    p_total: u64,
    q_total: u64,
    q_support: usize,
    alpha: f64,
) -> f64 {
    // items are (p_count, q_count)
    let q_denom = q_total as f64 + alpha * q_support as f64;
    let mut sum = 0.0;
    for (pc, qc) in p_counts {
        if pc == 0 {
            continue;
        }
        let p = pc as f64 / p_total as f64;
        let q = (qc as f64 + alpha) / q_denom;
        sum += p * (p / q).ln();
    }
    sum.max(0.0)
}

/// KL divergence of the sample's unigram distribution from the reference's.
pub fn unigram_kl(sample: &DistStats, full: &DistStats) -> f64 {
    kl(
        sample
            .unigram
            .iter()
            .map(|(w, c)| (*c, full.unigram.get(w).copied().unwrap_or(0)))
            .collect::<Vec<_>>()
            .into_iter(),
        sample.tokens.max(1),
        full.tokens.max(1),
        full.unigram.len().max(1),
        0.5,
    )
}

/// KL divergence of the sample's bigram distribution from the reference's.
pub fn bigram_kl(sample: &DistStats, full: &DistStats) -> f64 {
    kl(
        sample
            .bigram
            .iter()
            .map(|(b, c)| (*c, full.bigram.get(b).copied().unwrap_or(0)))
            .collect::<Vec<_>>()
            .into_iter(),
        sample.bigrams.max(1),
        full.bigrams.max(1),
        full.bigram.len().max(1),
        0.5,
    )
}

/// §3.1 coverage numbers: fraction of the full vocabulary covered by the
/// union and by the intersection of the sub-corpora vocabularies.
pub fn vocab_coverage(subs: &[DistStats], full: &DistStats) -> (f64, f64) {
    let full_vocab = full.vocab_set();
    if full_vocab.is_empty() || subs.is_empty() {
        return (0.0, 0.0);
    }
    let mut union = std::collections::HashSet::new();
    let mut intersection = subs[0].vocab_set();
    for s in subs {
        let vs = s.vocab_set();
        union.extend(vs.iter().copied());
        intersection = intersection.intersection(&vs).copied().collect();
    }
    (
        union.intersection(&full_vocab).count() as f64 / full_vocab.len() as f64,
        intersection.intersection(&full_vocab).count() as f64 / full_vocab.len() as f64,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus_of(sents: Vec<Vec<u32>>) -> Corpus {
        Corpus::new(sents)
    }

    #[test]
    fn counts_unigrams_and_bigrams() {
        let c = corpus_of(vec![vec![1, 2, 3], vec![2, 2]]);
        let s = DistStats::from_corpus(&c);
        assert_eq!(s.tokens, 5);
        assert_eq!(s.bigrams, 3);
        assert_eq!(s.unigram[&2], 3);
        assert_eq!(s.bigram[&(1, 2)], 1);
        assert_eq!(s.bigram[&(2, 2)], 1);
    }

    #[test]
    fn kl_zero_for_identical_distributions() {
        let c = corpus_of((0..100).map(|i| vec![i % 7, (i + 1) % 7]).collect());
        let s = DistStats::from_corpus(&c);
        let d = unigram_kl(&s, &s);
        assert!(d < 0.01, "self-KL should be ~0, got {d}");
    }

    #[test]
    fn kl_increases_with_distribution_skew() {
        // full corpus: uniform over 10 words; skewed sample: only 2 words
        let full = DistStats::from_corpus(&corpus_of(
            (0..1000).map(|i| vec![i % 10, (i + 1) % 10]).collect(),
        ));
        let uniform_sample = DistStats::from_corpus(&corpus_of(
            (0..100).map(|i| vec![i % 10, (i + 1) % 10]).collect(),
        ));
        let skewed_sample = DistStats::from_corpus(&corpus_of(
            (0..100).map(|i| vec![i % 2, (i + 1) % 2]).collect(),
        ));
        assert!(unigram_kl(&skewed_sample, &full) > unigram_kl(&uniform_sample, &full) + 0.3);
        assert!(bigram_kl(&skewed_sample, &full) > bigram_kl(&uniform_sample, &full));
    }

    #[test]
    fn kl_is_nonnegative() {
        let full = DistStats::from_corpus(&corpus_of(
            (0..500).map(|i| vec![i % 20, i % 3]).collect(),
        ));
        let sample = DistStats::from_corpus(&corpus_of(
            (0..50).map(|i| vec![i % 20, i % 3]).collect(),
        ));
        assert!(unigram_kl(&sample, &full) >= 0.0);
        assert!(bigram_kl(&sample, &full) >= 0.0);
    }

    #[test]
    fn coverage_union_and_intersection() {
        let full = DistStats::from_corpus(&corpus_of(vec![vec![0, 1, 2, 3]]));
        let s1 = DistStats::from_corpus(&corpus_of(vec![vec![0, 1]]));
        let s2 = DistStats::from_corpus(&corpus_of(vec![vec![1, 2]]));
        let (union, inter) = vocab_coverage(&[s1, s2], &full);
        assert!((union - 0.75).abs() < 1e-9); // {0,1,2} of 4
        assert!((inter - 0.25).abs() < 1e-9); // {1} of 4
    }

    #[test]
    fn coverage_handles_empty() {
        let full = DistStats::default();
        assert_eq!(vocab_coverage(&[], &full), (0.0, 0.0));
    }
}
