//! Reducer side of the train phase: one backend-resident sub-model per
//! reducer.
//!
//! A [`TrainReducer`] consumes the sentences its mapper routed to it and
//! feeds them to its [`SubModelTrainer`]. Reducers share **nothing** with
//! each other — no parameters, no RNG, no locks — which is the paper's
//! central asynchrony claim. At each round barrier the partial batch is
//! flushed and the running loss counters are snapshotted, giving the
//! per-epoch loss curve the e2e example logs.

use crate::exec::mapreduce::Reducer;
use crate::runtime::backend::Backend;
use crate::runtime::params::Metrics;
use crate::sgns::trainer::SubModelTrainer;

pub struct TrainReducer<'b, B: Backend> {
    pub trainer: SubModelTrainer<'b, B>,
    /// mean loss per finished epoch (delta of the running counters)
    pub epoch_mean_loss: Vec<f64>,
    prev: Metrics,
    /// first error encountered (training continues degenerate after that;
    /// the leader surfaces it)
    pub error: Option<String>,
}

impl<'b, B: Backend> TrainReducer<'b, B> {
    pub fn new(trainer: SubModelTrainer<'b, B>) -> Self {
        Self {
            trainer,
            epoch_mean_loss: Vec::new(),
            prev: Metrics::default(),
            error: None,
        }
    }

    /// Reinstate the per-epoch loss bookkeeping after the wrapped trainer
    /// was restored from a checkpoint: the epochs already recorded plus
    /// the exact counter baseline the next epoch's delta subtracts.
    /// Without the baseline the first post-resume epoch would recount the
    /// pre-crash loss and the curve would diverge from an uninterrupted
    /// run.
    pub fn resume_loss_baseline(&mut self, epoch_mean_loss: Vec<f64>, prev: Metrics) {
        self.epoch_mean_loss = epoch_mean_loss;
        self.prev = prev;
    }

    fn consume(&mut self, sentence_id: u64, sentence: &[u32]) {
        if self.error.is_some() {
            return;
        }
        if let Err(e) = self.trainer.push_sentence(sentence_id, sentence) {
            self.error = Some(e);
        }
    }

    fn finish_round(&mut self) {
        if self.error.is_some() {
            return;
        }
        if let Err(e) = self.trainer.flush() {
            self.error = Some(e);
            return;
        }
        match self.trainer.metrics() {
            Ok(m) => {
                let d_loss = m.loss_sum - self.prev.loss_sum;
                let d_ex = m.examples - self.prev.examples;
                self.epoch_mean_loss
                    .push(if d_ex > 0.0 { d_loss / d_ex } else { 0.0 });
                self.prev = m;
            }
            Err(e) => self.error = Some(e),
        }
    }
}

/// Borrowed-sentence feed — the in-process path, where the corpus
/// outlives the MapReduce scope and channels carry zero-copy slices.
impl<'b, 'c, B: Backend> Reducer<(u64, &'c [u32])> for TrainReducer<'b, B> {
    fn reduce(&mut self, (sentence_id, sentence): (u64, &'c [u32])) {
        self.consume(sentence_id, sentence);
    }

    fn end_round(&mut self, _round: usize) {
        self.finish_round();
    }
}

/// Owned-sentence feed — the multi-process path, where sentences are
/// streamed off disk and owned by the message itself.
impl<'b, B: Backend> Reducer<(u64, Vec<u32>)> for TrainReducer<'b, B> {
    fn reduce(&mut self, (sentence_id, sentence): (u64, Vec<u32>)) {
        self.consume(sentence_id, &sentence);
    }

    fn end_round(&mut self, _round: usize) {
        self.finish_round();
    }
}
