//! Mapper side of the train phase: stateless sentence routing.
//!
//! A [`SentenceRouter`] is constructed fresh for every (epoch, mapper
//! shard) — it holds nothing but a handle to the [`Divider`], whose
//! counter-based hashing makes every routing decision a pure function of
//! (seed, epoch, sentence index, sub-model). Sentences are routed by
//! reference: the corpus outlives the MapReduce scope, so the channels
//! carry `&[u32]` with zero copies.
//!
//! Two [`RoundSource`]s feed the mappers:
//!
//! * [`CorpusSource`] — the in-process path: shard = contiguous sentence
//!   range of an in-memory [`Corpus`], items borrowed with zero copies;
//! * [`ShardFileSource`] — the multi-process path: shard = contiguous
//!   range of on-disk `shard_*.bin` files streamed one sentence at a
//!   time, so a training worker's peak corpus memory is a single
//!   sentence regardless of corpus size. Global sentence indices are
//!   assigned by concatenating the files in numeric order, making every
//!   routing/RNG decision identical to the in-process path over the same
//!   data.

use super::divider::Divider;
use crate::exec::mapreduce::{Mapper, RoundSource};
use crate::text::corpus::Corpus;
use crate::text::feed::ShardManifest;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Routed sentence ids pack `(epoch, global sentence index)` into one
/// `u64`: the low [`SID_INDEX_BITS`] bits carry the index, the high bits
/// the epoch. Reducers derive **all** per-sentence randomness (window
/// draws, subsampling, negatives) from this id, so an overflow of either
/// field would silently collide RNG streams across sentences or epochs
/// and corrupt training. The packing is therefore guarded: corpora are
/// limited to [`MAX_ROUTED_SENTENCES`] sentences (2^40 ≈ 1.1 × 10^12) and
/// runs to [`MAX_ROUTED_EPOCHS`] epochs (2^24 ≈ 1.7 × 10^7) — router
/// constructors reject anything beyond, and `pack_sid` debug-asserts per
/// call.
pub const SID_INDEX_BITS: u32 = 40;
/// Hard corpus-size limit implied by the sid packing (exclusive).
pub const MAX_ROUTED_SENTENCES: u64 = 1 << SID_INDEX_BITS;
/// Hard epoch-count limit implied by the sid packing (exclusive).
pub const MAX_ROUTED_EPOCHS: u64 = 1 << (64 - SID_INDEX_BITS);

/// Pack an (epoch, sentence index) pair into a routed sentence id. See
/// the module constants for the documented field limits.
#[inline]
pub fn pack_sid(epoch: usize, idx: usize) -> u64 {
    debug_assert!(
        (epoch as u64) < MAX_ROUTED_EPOCHS,
        "epoch {epoch} overflows the {}-bit sid epoch field",
        64 - SID_INDEX_BITS
    );
    debug_assert!(
        (idx as u64) < MAX_ROUTED_SENTENCES,
        "sentence index {idx} overflows the {SID_INDEX_BITS}-bit sid index field"
    );
    ((epoch as u64) << SID_INDEX_BITS) | idx as u64
}

/// Cheap release-mode guard shared by the router constructors: one check
/// per (epoch, mapper shard), not per sentence.
fn assert_sid_capacity(total_sentences: usize, epoch: usize) {
    assert!(
        (total_sentences as u64) <= MAX_ROUTED_SENTENCES,
        "corpus has {total_sentences} sentences but sid packing supports at most \
         {MAX_ROUTED_SENTENCES} (2^{SID_INDEX_BITS}) — widen the sid layout before \
         training corpora this large"
    );
    assert!(
        (epoch as u64) < MAX_ROUTED_EPOCHS,
        "epoch {epoch} exceeds the sid packing limit of {MAX_ROUTED_EPOCHS} epochs"
    );
}

/// RoundSource over an in-memory corpus: shard = contiguous sentence range,
/// items are (global sentence index, sentence).
pub struct CorpusSource<'c> {
    pub corpus: &'c Corpus,
}

impl<'c> RoundSource for CorpusSource<'c> {
    type Item = (usize, &'c [u32]);

    fn shard(
        &self,
        _round: usize,
        shard: usize,
        num_shards: usize,
    ) -> Box<dyn Iterator<Item = (usize, &'c [u32])> + '_> {
        let range = self.corpus.shard_range(shard, num_shards);
        let lo = range.start;
        Box::new(
            self.corpus.sentences[range]
                .iter()
                .enumerate()
                .map(move |(i, s)| (lo + i, s.as_slice())),
        )
    }
}

/// The mapper: applies the divider for the current epoch.
pub struct SentenceRouter {
    divider: Arc<Divider>,
    epoch: usize,
    targets: Vec<usize>, // reusable buffer
}

impl SentenceRouter {
    /// Panics if the divider's corpus size or the epoch exceed the sid
    /// packing limits ([`MAX_ROUTED_SENTENCES`] / [`MAX_ROUTED_EPOCHS`]).
    pub fn new(divider: Arc<Divider>, epoch: usize) -> Self {
        assert_sid_capacity(divider.total_sentences, epoch);
        Self {
            divider,
            epoch,
            targets: Vec::new(),
        }
    }
}

impl<'c> Mapper<(usize, &'c [u32]), (u64, &'c [u32])> for SentenceRouter {
    fn map(
        &mut self,
        (idx, sentence): (usize, &'c [u32]),
        emit: &mut dyn FnMut(usize, (u64, &'c [u32])),
    ) {
        self.divider.targets(self.epoch, idx, &mut self.targets);
        // the routed id mixes epoch and sentence index: reducers draw all
        // per-sentence randomness from it, so training is reproducible
        // regardless of mapper interleaving, and epochs differ (word2vec
        // re-draws windows/subsampling every pass)
        let sid = pack_sid(self.epoch, idx);
        for &t in &self.targets {
            emit(t, (sid, sentence));
        }
    }
}

/// The multi-process worker's mapper: routes with the same stateless
/// [`Divider`] and sid packing as [`SentenceRouter`], but keeps only the
/// sentences destined for **one** sub-model and emits them (owned — they
/// were just streamed off disk) to the single local reducer. Routing
/// decisions for every other sub-model are computed and discarded, which
/// is exactly the paper's zero-coordination property: a worker needs
/// nothing but `(seed, strategy, rate, epoch)` to agree with its peers on
/// the partition.
pub struct SubModelFilter {
    divider: Arc<Divider>,
    epoch: usize,
    submodel: usize,
    targets: Vec<usize>,
}

impl SubModelFilter {
    /// Panics if the divider's corpus size or the epoch exceed the sid
    /// packing limits, like [`SentenceRouter::new`].
    pub fn new(divider: Arc<Divider>, epoch: usize, submodel: usize) -> Self {
        assert_sid_capacity(divider.total_sentences, epoch);
        assert!(
            submodel < divider.num_submodels,
            "sub-model {submodel} out of range (divider has {})",
            divider.num_submodels
        );
        Self {
            divider,
            epoch,
            submodel,
            targets: Vec::new(),
        }
    }
}

impl Mapper<(usize, Vec<u32>), (u64, Vec<u32>)> for SubModelFilter {
    fn map(
        &mut self,
        (idx, sentence): (usize, Vec<u32>),
        emit: &mut dyn FnMut(usize, (u64, Vec<u32>)),
    ) {
        self.divider.targets(self.epoch, idx, &mut self.targets);
        if self.targets.contains(&self.submodel) {
            emit(0, (pack_sid(self.epoch, idx), sentence));
        }
    }
}

/// Disk-backed [`RoundSource`] over a directory of `shard_*.bin` files —
/// the corpus feed of a multi-process training worker.
///
/// A mapper shard is a contiguous range of shard *files* (numeric order,
/// see [`Corpus::shard_files`]); each file is streamed one sentence at a
/// time through [`Corpus::stream_shard`], so peak memory per mapper is a
/// single sentence. Items are `(global sentence index, sentence)` where
/// the global index treats the files as one concatenated corpus —
/// identical to the indices [`CorpusSource`] hands out over the same data
/// loaded in memory, which is what keeps the stateless routing and
/// per-sentence RNG of the two paths in exact agreement.
///
/// `RoundSource` iterators cannot carry errors, so mid-stream I/O
/// failures latch into the source (first error wins) and end that
/// mapper's iteration early; callers **must** check [`Self::take_error`]
/// after the run — a worker that hit a latched error aborts instead of
/// publishing a sub-model trained on a truncated corpus.
pub struct ShardFileSource {
    files: Vec<PathBuf>,
    /// global sentence index at which each file starts
    offsets: Vec<usize>,
    total: usize,
    error: Mutex<Option<String>>,
}

impl ShardFileSource {
    /// List and validate the shard files of `dir`: headers are read (and
    /// size-checked) up front to establish per-file sentence offsets; the
    /// sentence bodies stay on disk. An index gap is a hard error — this
    /// source treats the directory as the full concatenated corpus, and
    /// splicing around a hole would silently shift the global index (and
    /// with it every routing and RNG decision) of every sentence after it.
    pub fn open(dir: &Path) -> Result<Self, String> {
        let entries = Corpus::shard_entries(dir)
            .map_err(|e| format!("list shards in {}: {e}", dir.display()))?;
        if entries.is_empty() {
            return Err(format!("no shard_*.bin files in {}", dir.display()));
        }
        if let Some(gap) = Corpus::first_shard_gap(&entries) {
            return Err(format!(
                "shard dir {} is missing shard index {gap} ({} shard files present) — \
                 refusing to train on a spliced corpus",
                dir.display(),
                entries.len()
            ));
        }
        let files: Vec<PathBuf> = entries.into_iter().map(|(_, p)| p).collect();
        let mut offsets = Vec::with_capacity(files.len());
        let mut total = 0usize;
        for f in &files {
            offsets.push(total);
            let reader = Corpus::stream_shard(f)
                .map_err(|e| format!("open shard {}: {e}", f.display()))?;
            total += reader.sentence_count();
        }
        // A manifest (atomic-ingest dirs) is ground truth when present: the
        // file listing alone cannot tell a finished corpus from the gap-free
        // shard prefix an ingest that died mid-run leaves behind.
        if let Some(man) = ShardManifest::load(dir)? {
            if !man.complete {
                return Err(format!(
                    "{} holds an unfinished ingest ({} shards published, manifest not \
                     complete) — re-run ingest, or train in feed mode while it runs",
                    dir.display(),
                    man.num_shards()
                ));
            }
            if man.num_shards() != files.len() || man.total_sentences() as usize != total {
                return Err(format!(
                    "{} disagrees with its manifest: {} shard files / {} sentences on \
                     disk vs {} / {} recorded",
                    dir.display(),
                    files.len(),
                    total,
                    man.num_shards(),
                    man.total_sentences()
                ));
            }
        }
        Ok(Self {
            files,
            offsets,
            total,
            error: Mutex::new(None),
        })
    }

    /// Total sentences across all shard files (from the validated headers).
    pub fn total_sentences(&self) -> usize {
        self.total
    }

    /// Number of shard files.
    pub fn num_files(&self) -> usize {
        self.files.len()
    }

    /// Take the first streaming error latched during iteration, if any.
    pub fn take_error(&self) -> Option<String> {
        self.error.lock().unwrap().take()
    }

    fn latch_error(&self, msg: String) {
        let mut slot = self.error.lock().unwrap();
        if slot.is_none() {
            *slot = Some(msg);
        }
    }

    /// Stream one file's sentences with global indices, latching errors.
    fn stream_file(&self, file: usize) -> impl Iterator<Item = (usize, Vec<u32>)> + '_ {
        let path = &self.files[file];
        let base = self.offsets[file];
        let mut reader = match Corpus::stream_shard(path) {
            Ok(r) => Some(r),
            Err(e) => {
                self.latch_error(format!("reopen shard {}: {e}", path.display()));
                None
            }
        };
        let mut local = 0usize;
        std::iter::from_fn(move || {
            let r = reader.as_mut()?;
            match r.next() {
                Some(Ok(sentence)) => {
                    let idx = base + local;
                    local += 1;
                    Some((idx, sentence))
                }
                Some(Err(e)) => {
                    self.latch_error(format!("stream shard {}: {e}", path.display()));
                    reader = None;
                    None
                }
                None => None,
            }
        })
    }
}

impl RoundSource for ShardFileSource {
    type Item = (usize, Vec<u32>);

    fn shard(
        &self,
        _round: usize,
        shard: usize,
        num_shards: usize,
    ) -> Box<dyn Iterator<Item = (usize, Vec<u32>)> + '_> {
        // contiguous partition of the *files* across mappers
        let n = self.files.len();
        let chunk = n.div_ceil(num_shards.max(1)).max(1);
        let lo = (shard * chunk).min(n);
        let hi = ((shard + 1) * chunk).min(n);
        Box::new((lo..hi).flat_map(move |f| self.stream_file(f)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::mapreduce::{MapReduce, Reducer};
    use crate::util::config::DivideStrategy;

    #[derive(Default)]
    struct Collect {
        sentences: Vec<Vec<u32>>,
        rounds: usize,
    }

    impl<'c> Reducer<(u64, &'c [u32])> for Collect {
        fn reduce(&mut self, (_, s): (u64, &'c [u32])) {
            self.sentences.push(s.to_vec());
        }
        fn end_round(&mut self, _r: usize) {
            self.rounds += 1;
        }
    }

    fn corpus(n: usize) -> Corpus {
        Corpus::new((0..n as u32).map(|i| vec![i, i + 1]).collect())
    }

    #[test]
    fn equal_partitioning_routes_contiguous_blocks() {
        let c = corpus(100);
        let divider = Arc::new(
            Divider::new(DivideStrategy::EqualPartitioning, 25.0, 7, c.len()).unwrap(),
        );
        let mr = MapReduce {
            num_mappers: 3,
            queue_capacity: 16,
        };
        let mut reducers: Vec<Collect> = (0..4).map(|_| Collect::default()).collect();
        mr.run(
            1,
            &CorpusSource { corpus: &c },
            |epoch, _shard| SentenceRouter::new(Arc::clone(&divider), epoch),
            &mut reducers,
        );
        // each reducer got its contiguous quarter (order within may vary
        // across mapper threads)
        for (r, red) in reducers.iter().enumerate() {
            assert_eq!(red.sentences.len(), 25, "reducer {r}");
            let mut firsts: Vec<u32> = red.sentences.iter().map(|s| s[0]).collect();
            firsts.sort_unstable();
            assert_eq!(firsts[0] as usize, r * 25);
            assert_eq!(*firsts.last().unwrap() as usize, r * 25 + 24);
        }
    }

    #[test]
    fn sid_packing_is_unique_at_the_boundaries() {
        assert_eq!(pack_sid(0, 0), 0);
        // epoch and index fields must not bleed into each other: the
        // largest index of epoch 0 stays below the smallest sid of epoch 1
        let max_idx = (MAX_ROUTED_SENTENCES - 1) as usize;
        assert!(pack_sid(0, max_idx) < pack_sid(1, 0));
        assert_ne!(pack_sid(1, 0), pack_sid(0, max_idx) + 2);
        // round-trip extraction at an arbitrary interior point
        let sid = pack_sid(3, 17);
        assert_eq!(sid >> SID_INDEX_BITS, 3);
        assert_eq!(sid & (MAX_ROUTED_SENTENCES - 1), 17);
        // the extreme corner uses every bit without wrapping
        let hi = pack_sid((MAX_ROUTED_EPOCHS - 1) as usize, max_idx);
        assert_eq!(hi, u64::MAX);
    }

    #[test]
    #[should_panic(expected = "sid packing")]
    fn router_rejects_corpora_beyond_the_sid_limit() {
        let mut d = Divider::new(DivideStrategy::Shuffle, 50.0, 1, 10).unwrap();
        // fake a corpus one past the 2^40-sentence limit (constructing a
        // real one is obviously not possible in a test)
        d.total_sentences = (MAX_ROUTED_SENTENCES + 1) as usize;
        let _ = SentenceRouter::new(Arc::new(d), 0);
    }

    #[test]
    #[should_panic(expected = "sid packing")]
    fn router_rejects_epochs_beyond_the_sid_limit() {
        let d = Divider::new(DivideStrategy::Shuffle, 50.0, 1, 10).unwrap();
        let _ = SentenceRouter::new(Arc::new(d), MAX_ROUTED_EPOCHS as usize);
    }

    fn shard_dir(name: &str, c: &Corpus, shards: usize) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "dw2v_mapper_test_{name}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        c.write_sharded(&dir, shards).unwrap();
        dir
    }

    #[test]
    fn shard_file_source_matches_in_memory_indices() {
        let c = corpus(57);
        let dir = shard_dir("indices", &c, 5);
        let src = ShardFileSource::open(&dir).unwrap();
        assert_eq!(src.total_sentences(), 57);
        assert_eq!(src.num_files(), 5);
        // a single mapper shard streams the whole corpus in global order
        let all: Vec<(usize, Vec<u32>)> = src.shard(0, 0, 1).collect();
        assert_eq!(all.len(), 57);
        for (i, (idx, s)) in all.iter().enumerate() {
            assert_eq!(*idx, i);
            assert_eq!(s, &c.sentences[i]);
        }
        // multiple mapper shards partition the same items
        let mut union: Vec<(usize, Vec<u32>)> = (0..3).flat_map(|m| src.shard(0, m, 3)).collect();
        union.sort_by_key(|(i, _)| *i);
        assert_eq!(union, all);
        assert!(src.take_error().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shard_file_source_rejects_index_gaps() {
        let c = corpus(40);
        let dir = shard_dir("gap", &c, 4);
        std::fs::remove_file(dir.join("shard_1.bin")).unwrap();
        let err = ShardFileSource::open(&dir).unwrap_err();
        assert!(
            err.contains("missing shard index 1"),
            "gap must be a named hard error: {err}"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shard_file_source_trusts_the_manifest_over_the_listing() {
        use crate::text::feed::ShardManifest;
        let c = corpus(40);
        let dir = shard_dir("manifest", &c, 4);
        // an incomplete manifest marks an ingest that died mid-run: the
        // shard prefix on disk is gap-free yet still a truncated corpus
        let mut man = ShardManifest {
            complete: false,
            shard_sentences: vec![10, 10, 10, 10],
            tokens: c.total_tokens(),
            schedule: None,
        };
        man.publish(&dir).unwrap();
        let err = ShardFileSource::open(&dir).unwrap_err();
        assert!(err.contains("unfinished ingest"), "{err}");
        // a complete manifest that disagrees with the files is also fatal
        man.complete = true;
        man.shard_sentences = vec![10, 10, 10];
        man.publish(&dir).unwrap();
        let err = ShardFileSource::open(&dir).unwrap_err();
        assert!(err.contains("disagrees with its manifest"), "{err}");
        // and a matching one validates cleanly
        man.shard_sentences = vec![10, 10, 10, 10];
        man.publish(&dir).unwrap();
        assert_eq!(ShardFileSource::open(&dir).unwrap().total_sentences(), 40);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shard_file_source_latches_streaming_errors() {
        let c = corpus(30);
        let dir = shard_dir("latch", &c, 3);
        // corrupt the middle shard *after* open() validated headers: chop
        // its tail so streaming hits a truncated sentence
        let victim = dir.join("shard_1.bin");
        let bytes = std::fs::read(&victim).unwrap();
        std::fs::write(&victim, &bytes[..bytes.len() - 3]).unwrap();
        let src = ShardFileSource::open(&dir).unwrap();
        let got: Vec<(usize, Vec<u32>)> = src.shard(0, 0, 1).collect();
        // iteration stopped early instead of fabricating data …
        assert!(got.len() < 30, "got {} items", got.len());
        // … and the error is latched for the caller
        let err = src.take_error().expect("error must be latched");
        assert!(err.contains("shard"), "{err}");
        assert!(src.take_error().is_none(), "take_error drains the latch");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn submodel_filter_routes_exactly_its_share() {
        let c = corpus(400);
        let divider = Arc::new(
            Divider::new(DivideStrategy::Shuffle, 25.0, 11, c.len()).unwrap(),
        );
        // reference: what the in-process router sends to reducer 2
        let mut expect: Vec<(u64, Vec<u32>)> = Vec::new();
        let mut buf = Vec::new();
        for (i, s) in c.sentences.iter().enumerate() {
            divider.targets(1, i, &mut buf);
            if buf.contains(&2) {
                expect.push((pack_sid(1, i), s.clone()));
            }
        }
        let mut filter = SubModelFilter::new(Arc::clone(&divider), 1, 2);
        let mut got: Vec<(u64, Vec<u32>)> = Vec::new();
        for (i, s) in c.sentences.iter().enumerate() {
            filter.map((i, s.clone()), &mut |target, item| {
                assert_eq!(target, 0, "filter must emit to the single local reducer");
                got.push(item);
            });
        }
        assert_eq!(got, expect);
        assert!(!got.is_empty());
    }

    #[test]
    fn shuffle_rounds_differ_but_rates_hold() {
        let c = corpus(2000);
        let divider =
            Arc::new(Divider::new(DivideStrategy::Shuffle, 20.0, 9, c.len()).unwrap());
        let mr = MapReduce {
            num_mappers: 2,
            queue_capacity: 64,
        };
        let mut reducers: Vec<Collect> = (0..5).map(|_| Collect::default()).collect();
        let stats = mr.run(
            2,
            &CorpusSource { corpus: &c },
            |epoch, _| SentenceRouter::new(Arc::clone(&divider), epoch),
            &mut reducers,
        );
        assert_eq!(stats.rounds, 2);
        for red in &reducers {
            assert_eq!(red.rounds, 2);
            // ~20% per epoch × 2 epochs = ~800
            let frac = red.sentences.len() as f64 / (2.0 * 2000.0);
            assert!((frac - 0.2).abs() < 0.03, "frac={frac}");
        }
    }
}
