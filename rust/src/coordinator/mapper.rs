//! Mapper side of the train phase: stateless sentence routing.
//!
//! A [`SentenceRouter`] is constructed fresh for every (epoch, mapper
//! shard) — it holds nothing but a handle to the [`Divider`], whose
//! counter-based hashing makes every routing decision a pure function of
//! (seed, epoch, sentence index, sub-model). Sentences are routed by
//! reference: the corpus outlives the MapReduce scope, so the channels
//! carry `&[u32]` with zero copies.

use super::divider::Divider;
use crate::exec::mapreduce::{Mapper, RoundSource};
use crate::text::corpus::Corpus;
use std::sync::Arc;

/// RoundSource over an in-memory corpus: shard = contiguous sentence range,
/// items are (global sentence index, sentence).
pub struct CorpusSource<'c> {
    pub corpus: &'c Corpus,
}

impl<'c> RoundSource for CorpusSource<'c> {
    type Item = (usize, &'c [u32]);

    fn shard(
        &self,
        _round: usize,
        shard: usize,
        num_shards: usize,
    ) -> Box<dyn Iterator<Item = (usize, &'c [u32])> + '_> {
        let range = self.corpus.shard_range(shard, num_shards);
        let lo = range.start;
        Box::new(
            self.corpus.sentences[range]
                .iter()
                .enumerate()
                .map(move |(i, s)| (lo + i, s.as_slice())),
        )
    }
}

/// The mapper: applies the divider for the current epoch.
pub struct SentenceRouter {
    divider: Arc<Divider>,
    epoch: usize,
    targets: Vec<usize>, // reusable buffer
}

impl SentenceRouter {
    pub fn new(divider: Arc<Divider>, epoch: usize) -> Self {
        Self {
            divider,
            epoch,
            targets: Vec::new(),
        }
    }
}

impl<'c> Mapper<(usize, &'c [u32]), (u64, &'c [u32])> for SentenceRouter {
    fn map(
        &mut self,
        (idx, sentence): (usize, &'c [u32]),
        emit: &mut dyn FnMut(usize, (u64, &'c [u32])),
    ) {
        self.divider.targets(self.epoch, idx, &mut self.targets);
        // the routed id mixes epoch and sentence index: reducers draw all
        // per-sentence randomness from it, so training is reproducible
        // regardless of mapper interleaving, and epochs differ (word2vec
        // re-draws windows/subsampling every pass)
        let sid = (self.epoch as u64) << 40 | idx as u64;
        for &t in &self.targets {
            emit(t, (sid, sentence));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::mapreduce::{MapReduce, Reducer};
    use crate::util::config::DivideStrategy;

    #[derive(Default)]
    struct Collect {
        sentences: Vec<Vec<u32>>,
        rounds: usize,
    }

    impl<'c> Reducer<(u64, &'c [u32])> for Collect {
        fn reduce(&mut self, (_, s): (u64, &'c [u32])) {
            self.sentences.push(s.to_vec());
        }
        fn end_round(&mut self, _r: usize) {
            self.rounds += 1;
        }
    }

    fn corpus(n: usize) -> Corpus {
        Corpus::new((0..n as u32).map(|i| vec![i, i + 1]).collect())
    }

    #[test]
    fn equal_partitioning_routes_contiguous_blocks() {
        let c = corpus(100);
        let divider = Arc::new(Divider::new(
            DivideStrategy::EqualPartitioning,
            25.0,
            7,
            c.len(),
        ));
        let mr = MapReduce {
            num_mappers: 3,
            queue_capacity: 16,
        };
        let mut reducers: Vec<Collect> = (0..4).map(|_| Collect::default()).collect();
        mr.run(
            1,
            &CorpusSource { corpus: &c },
            |epoch, _shard| SentenceRouter::new(Arc::clone(&divider), epoch),
            &mut reducers,
        );
        // each reducer got its contiguous quarter (order within may vary
        // across mapper threads)
        for (r, red) in reducers.iter().enumerate() {
            assert_eq!(red.sentences.len(), 25, "reducer {r}");
            let mut firsts: Vec<u32> = red.sentences.iter().map(|s| s[0]).collect();
            firsts.sort_unstable();
            assert_eq!(firsts[0] as usize, r * 25);
            assert_eq!(*firsts.last().unwrap() as usize, r * 25 + 24);
        }
    }

    #[test]
    fn shuffle_rounds_differ_but_rates_hold() {
        let c = corpus(2000);
        let divider = Arc::new(Divider::new(DivideStrategy::Shuffle, 20.0, 9, c.len()));
        let mr = MapReduce {
            num_mappers: 2,
            queue_capacity: 64,
        };
        let mut reducers: Vec<Collect> = (0..5).map(|_| Collect::default()).collect();
        let stats = mr.run(
            2,
            &CorpusSource { corpus: &c },
            |epoch, _| SentenceRouter::new(Arc::clone(&divider), epoch),
            &mut reducers,
        );
        assert_eq!(stats.rounds, 2);
        for red in &reducers {
            assert_eq!(red.rounds, 2);
            // ~20% per epoch × 2 epochs = ~800
            let frac = red.sentences.len() as f64 / (2.0 * 2000.0);
            assert!((frac - 0.2).abs() < 0.03, "frac={frac}");
        }
    }
}
