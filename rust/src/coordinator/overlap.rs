//! Ingest-while-training overlap: run the raw-text ingest and the
//! multi-process training fleet **concurrently** against one shard
//! directory, and still merge bitwise identical to a back-to-back run.
//!
//! The paper trains on corpora large enough that preprocessing is itself
//! a long-running job; serializing "ingest, then train" leaves the
//! machine half idle twice. The overlap contract that makes concurrency
//! safe *and* deterministic is split across three modules:
//!
//! * the ingest side ([`ingest_file_overlapped`]) publishes every shard
//!   atomically and, **before the first shard**, a schedule block with
//!   the exact `total_sentences` and bits-exact per-epoch pair sum the
//!   workers would otherwise compute themselves;
//! * the reader side ([`crate::text::feed::ShardFeed`]) follows the
//!   manifest — never the directory listing — yielding shard `i` the
//!   moment it is published and blocking (not failing) on shard `i+1`;
//! * the process layer (`super::procs`, feed mode) takes the divider
//!   total and lr denominator from the schedule block, so a worker's
//!   very first gradient is computed from the same numbers as in a
//!   sequential run even though most shards don't exist yet.
//!
//! [`run_overlapped`] is the driver tying those together: spawn the
//! ingest on a thread, wait for the schedule block, then run the
//! supervised fleet in feed mode. Workers blocked on an unpublished
//! shard beacon a `waiting` phase, which the supervisor's byte-change
//! stall detector already treats as healthy — a slow ingest never gets a
//! worker killed, while a dead one surfaces as a feed timeout error.

use super::procs::{self, ProcsOptions};
use super::supervisor::{run_supervised, SupervisedReport, SupervisorOptions};
use crate::info;
use crate::obs::journal::{self, u64s};
use crate::text::feed::{self, FeedOptions};
use crate::text::ingest::{ingest_file_overlapped, IngestConfig, IngestOutput, OverlapOptions};
use crate::text::vocab::Vocab;
use crate::transport::Transport;
use crate::util::config::ExperimentConfig;
use crate::world::World;
use std::path::PathBuf;

/// What an overlapped run needs beyond the plain multi-process options:
/// where the raw text lives and how to ingest it.
pub struct OverlapRunOptions {
    /// raw text input file
    pub input: PathBuf,
    /// ingest knobs (vocab pruning, chunking, shard sizing)
    pub ingest: IngestConfig,
    /// schedule-pass parameters + the shard-delay test hook
    pub overlap: OverlapOptions,
    /// `questions-words.txt` benchmark file for the eval tail, if any —
    /// loaded only once the ingest freezes the vocabulary
    pub eval: Option<PathBuf>,
    /// poll cadence / progress deadline for the schedule wait (workers
    /// use their own default [`FeedOptions`])
    pub feed: FeedOptions,
}

/// Result of [`run_overlapped`]: the ingest report, the vocabulary it
/// froze, and the supervised training report it overlapped with.
pub struct OverlapReport {
    pub ingest: IngestOutput,
    pub vocab: Vocab,
    pub sup: SupervisedReport,
}

/// Ingest `ov.input` into `opts.shard_dir` while training the fleet out
/// of the same directory. Blocks until both finish. If both sides fail,
/// the ingest error wins the report — a dead ingest is the usual root
/// cause of the workers' feed timeouts.
pub fn run_overlapped(
    cfg: &ExperimentConfig,
    opts: &ProcsOptions,
    sup: &SupervisorOptions,
    ov: &OverlapRunOptions,
) -> Result<OverlapReport, String> {
    // The ingest clears stale shards only after its vocabulary pass, so a
    // manifest left by a previous run would still be on disk when we poll
    // for the schedule below — and we would happily spawn the fleet
    // against last run's corpus. Clear it here, before ingest starts.
    let transport = Transport::fs(&opts.shard_dir, &opts.out_dir);
    transport.shards.prepare_ingest_dir()?;

    let input = ov.input.clone();
    let shard_dir = opts.shard_dir.clone();
    let icfg = ov.ingest.clone();
    let ocfg = ov.overlap.clone();
    info!(
        "overlap: ingesting {} into {} while the fleet trains",
        input.display(),
        shard_dir.display()
    );
    let ingest_thread =
        std::thread::spawn(move || ingest_file_overlapped(&input, &shard_dir, &icfg, &ocfg));

    // Everything below must not early-return before the join, or a failed
    // spawn would leave the ingest thread detached mid-write.
    let train = || -> Result<(Vocab, SupervisedReport), String> {
        // the overlap journal lives in the shard dir (out_dir doesn't
        // exist yet, and prepare_run sweeps stale events files from it);
        // a fresh run replaces last run's file like the ingest journal does
        let jrn = journal::fresh_journal(&opts.shard_dir, "overlap");
        let wait_started = std::time::Instant::now();
        let (man, sched) = feed::wait_for_schedule(&opts.shard_dir, &ov.feed, || {})?;
        jrn.event(
            "schedule_ready",
            vec![
                ("wait_secs", crate::util::json::num(wait_started.elapsed().as_secs_f64())),
                ("sentences", u64s(sched.total_sentences)),
                ("shards_published", crate::util::json::inum(man.num_shards())),
            ],
        );
        info!(
            "overlap: schedule ready ({} sentences, {} shards published) — spawning workers",
            sched.total_sentences,
            man.num_shards()
        );
        // vocab.tsv is on disk before the schedule block, so the eval
        // suite can load here — while the shards are still being written
        let (vocab, suite) =
            World::vocab_and_suite_from_shards(&opts.shard_dir, ov.eval.as_deref())?;
        let wopts = ProcsOptions {
            worker_exe: opts.worker_exe.clone(),
            shard_dir: opts.shard_dir.clone(),
            out_dir: opts.out_dir.clone(),
            extra_env: {
                let mut env = opts.extra_env.clone();
                env.push(procs::feed_env_pair());
                env
            },
            connect: opts.connect.clone(),
        };
        run_supervised(cfg, &suite, &wopts, sup).map(|rep| (vocab, rep))
    };
    let trained = train();

    let ingested = ingest_thread
        .join()
        .unwrap_or_else(|_| Err("ingest thread panicked".to_string()));

    match (ingested, trained) {
        (Ok(ingest), Ok((vocab, sup))) => Ok(OverlapReport { ingest, vocab, sup }),
        (Err(e), _) => Err(format!("overlapped ingest failed: {e}")),
        (Ok(_), Err(e)) => Err(e),
    }
}
