//! The divide phase: assigning sentences to sub-corpora.
//!
//! Three strategies from the paper (§3.1–3.2):
//!
//! * **EqualPartitioning** — sentence `i` goes to the single sub-corpus
//!   `i / (N/n)`; identical every epoch.
//! * **RandomSampling** — every (sentence, sub-corpus) pair is an
//!   independent Bernoulli(r/100) draw, *fixed across epochs* (the same
//!   sample is replayed every round).
//! * **Shuffle** — the same Bernoulli draws but re-randomized each epoch:
//!   a sub-model sees the same *fraction* of data every round but not the
//!   same sentences (the paper's stateless, regularizing contribution).
//!
//! All three are implemented **counter-based** (a hash of
//! (seed, strategy, sub-corpus, sentence[, epoch]) drives each decision),
//! so any mapper thread can compute any sentence's routing without shared
//! state or coordination — precisely the statelessness the paper claims
//! for its MapReduce mappers.

use crate::util::config::{validate_rate_percent, DivideStrategy};
use crate::util::rng::SplitMix64;

#[derive(Clone, Debug)]
pub struct Divider {
    pub strategy: DivideStrategy,
    pub num_submodels: usize,
    /// sampling rate r as a fraction (r% / 100)
    pub rate: f64,
    pub seed: u64,
    pub total_sentences: usize,
}

impl Divider {
    /// Build a divider for sampling rate `rate_percent`. The rate must lie
    /// in `(0, 100]` — see [`validate_rate_percent`]; out-of-range values
    /// (which used to saturate `num_submodels` to `usize::MAX` at `0` or
    /// yield nonsense Bernoulli rates when negative / `> 100`) are
    /// rejected with an error.
    pub fn new(
        strategy: DivideStrategy,
        rate_percent: f64,
        seed: u64,
        total_sentences: usize,
    ) -> Result<Self, String> {
        validate_rate_percent(rate_percent)?;
        let num = ((100.0 / rate_percent).round() as usize).max(1);
        Ok(Self {
            strategy,
            num_submodels: num,
            rate: rate_percent / 100.0,
            seed,
            total_sentences,
        })
    }

    /// Stateless uniform hash in [0,1) for one routing decision.
    #[inline]
    fn decision(&self, epoch: usize, sentence: usize, submodel: usize) -> f64 {
        // one SplitMix64 step over a mixed key: cheap, high-quality, and
        // reproducible regardless of mapper threading
        let epoch_key = match self.strategy {
            DivideStrategy::Shuffle => epoch as u64,
            _ => 0, // Random/Equal replay the same decisions each epoch
        };
        let key = self
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((sentence as u64).rotate_left(17))
            .wrapping_add((submodel as u64).rotate_left(39))
            .wrapping_add(epoch_key.rotate_left(51));
        let mut sm = SplitMix64::new(key);
        (sm.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Append the sub-model indices sentence `sentence` is routed to in
    /// `epoch` onto `out` (cleared first). A sentence may go to zero, one
    /// or several sub-corpora under Random/Shuffle.
    pub fn targets(&self, epoch: usize, sentence: usize, out: &mut Vec<usize>) {
        out.clear();
        match self.strategy {
            DivideStrategy::EqualPartitioning => {
                let chunk = self.total_sentences.div_ceil(self.num_submodels).max(1);
                out.push((sentence / chunk).min(self.num_submodels - 1));
            }
            DivideStrategy::RandomSampling | DivideStrategy::Shuffle => {
                for s in 0..self.num_submodels {
                    if self.decision(epoch, sentence, s) < self.rate {
                        out.push(s);
                    }
                }
            }
        }
    }

    /// Expected number of sentences routed to one sub-model per epoch.
    pub fn expected_per_submodel(&self) -> f64 {
        match self.strategy {
            DivideStrategy::EqualPartitioning => {
                self.total_sentences as f64 / self.num_submodels as f64
            }
            _ => self.total_sentences as f64 * self.rate,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect(d: &Divider, epoch: usize) -> Vec<Vec<usize>> {
        // per-submodel sentence lists
        let mut per: Vec<Vec<usize>> = vec![Vec::new(); d.num_submodels];
        let mut buf = Vec::new();
        for i in 0..d.total_sentences {
            d.targets(epoch, i, &mut buf);
            for &s in &buf {
                per[s].push(i);
            }
        }
        per
    }

    #[test]
    fn equal_partitioning_is_contiguous_and_disjoint() {
        let d = Divider::new(DivideStrategy::EqualPartitioning, 10.0, 1, 1000).unwrap();
        assert_eq!(d.num_submodels, 10);
        let per = collect(&d, 0);
        let mut all: Vec<usize> = per.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>()); // partition
        for (s, list) in per.iter().enumerate() {
            assert_eq!(list.len(), 100);
            assert_eq!(list[0], s * 100); // contiguous blocks
        }
        // identical across epochs
        assert_eq!(collect(&d, 0), collect(&d, 3));
    }

    #[test]
    fn random_sampling_rate_and_epoch_stability() {
        let d = Divider::new(DivideStrategy::RandomSampling, 10.0, 2, 5000).unwrap();
        let per0 = collect(&d, 0);
        let per5 = collect(&d, 5);
        assert_eq!(per0, per5, "RandomSampling must replay the same sample");
        for list in &per0 {
            let frac = list.len() as f64 / 5000.0;
            assert!((frac - 0.1).abs() < 0.02, "rate off: {frac}");
        }
    }

    #[test]
    fn shuffle_resamples_each_epoch() {
        let d = Divider::new(DivideStrategy::Shuffle, 10.0, 3, 5000).unwrap();
        let per0 = collect(&d, 0);
        let per1 = collect(&d, 1);
        assert_ne!(per0, per1, "Shuffle must draw fresh samples per epoch");
        for per in [&per0, &per1] {
            for list in per {
                let frac = list.len() as f64 / 5000.0;
                assert!((frac - 0.1).abs() < 0.02, "rate off: {frac}");
            }
        }
    }

    #[test]
    fn sentences_can_go_to_multiple_submodels() {
        let d = Divider::new(DivideStrategy::Shuffle, 50.0, 4, 2000).unwrap();
        assert_eq!(d.num_submodels, 2);
        let mut buf = Vec::new();
        let mut multi = 0;
        for i in 0..2000 {
            d.targets(0, i, &mut buf);
            if buf.len() > 1 {
                multi += 1;
            }
        }
        // P(both) = 0.25 -> expect ~500
        assert!(multi > 300, "expected overlapping assignment, got {multi}");
    }

    #[test]
    fn routing_is_order_independent() {
        // the same (epoch, sentence) query must give the same answer no
        // matter when it is asked — the statelessness property
        let d = Divider::new(DivideStrategy::Shuffle, 20.0, 5, 100).unwrap();
        let mut a = Vec::new();
        let mut b = Vec::new();
        d.targets(2, 57, &mut a);
        for i in (0..100).rev() {
            d.targets(2, i, &mut b); // interleave other queries
        }
        d.targets(2, 57, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_decorrelate() {
        let d1 = Divider::new(DivideStrategy::RandomSampling, 10.0, 100, 3000).unwrap();
        let d2 = Divider::new(DivideStrategy::RandomSampling, 10.0, 101, 3000).unwrap();
        assert_ne!(collect(&d1, 0), collect(&d2, 0));
    }

    #[test]
    fn expected_per_submodel() {
        let eq = Divider::new(DivideStrategy::EqualPartitioning, 10.0, 1, 1000).unwrap();
        assert_eq!(eq.expected_per_submodel(), 100.0);
        let sh = Divider::new(DivideStrategy::Shuffle, 10.0, 1, 1000).unwrap();
        assert_eq!(sh.expected_per_submodel(), 100.0);
    }

    #[test]
    fn theorem2_frequent_words_never_missed() {
        // Paper Theorem 2: with u = r/100 and sentence length ℓ, a word
        // with occurrence probability above 1-(1-u)^((1-u)/(ℓu)) is missed
        // by a sub-corpus with exponentially small probability. Empirical
        // check: plant a word in 2% of sentences (well above the u=0.1,
        // ℓ=20 threshold ≈ 0.0095 for per-token probability; per-sentence
        // here) and verify no sub-corpus misses it.
        let n_sentences = 20_000;
        let d = Divider::new(DivideStrategy::RandomSampling, 10.0, 77, n_sentences).unwrap();
        // the "word" occurs in every 50th sentence
        let occurs: Vec<usize> = (0..n_sentences).step_by(50).collect();
        let mut buf = Vec::new();
        let mut seen = vec![false; d.num_submodels];
        for &i in &occurs {
            d.targets(0, i, &mut buf);
            for &s in &buf {
                seen[s] = true;
            }
        }
        assert!(
            seen.iter().all(|&s| s),
            "a frequent word was missed by some sub-corpus: {seen:?}"
        );
    }

    #[test]
    fn out_of_range_rates_are_rejected() {
        // r = 0 used to make `(100.0 / r).round() as usize` saturate to
        // usize::MAX sub-models (an OOM on the reducer vec); negatives and
        // > 100 silently produced nonsense Bernoulli rates
        for bad in [0.0, -0.0, -5.0, 100.0001, 150.0, f64::NAN, f64::INFINITY] {
            for strategy in STRATEGIES {
                assert!(
                    Divider::new(strategy, bad, 1, 100).is_err(),
                    "rate {bad} must be rejected"
                );
            }
        }
        // boundaries: 0 is exclusive (checked above), 100 inclusive
        let d = Divider::new(DivideStrategy::Shuffle, 100.0, 1, 100).unwrap();
        assert_eq!(d.num_submodels, 1);
        // tiny-but-positive rates are legal
        let d = Divider::new(DivideStrategy::Shuffle, 0.01, 1, 100).unwrap();
        assert_eq!(d.num_submodels, 10_000);
    }

    #[test]
    fn rate_100_single_model_gets_everything() {
        let d = Divider::new(DivideStrategy::Shuffle, 100.0, 9, 500).unwrap();
        assert_eq!(d.num_submodels, 1);
        let per = collect(&d, 0);
        // Bernoulli(1.0) -> all sentences
        assert_eq!(per[0].len(), 500);
    }

    // ---- property-style tests over random corpora ---------------------------

    use crate::util::rng::Pcg64;

    const STRATEGIES: [DivideStrategy; 3] = [
        DivideStrategy::EqualPartitioning,
        DivideStrategy::RandomSampling,
        DivideStrategy::Shuffle,
    ];

    /// Every routing decision lands in bounds, no sub-model appears twice
    /// for one sentence, and EqualPartitioning multiplicity is exactly 1 —
    /// across random corpus sizes, rates, seeds and epochs.
    #[test]
    fn property_targets_are_within_bounds_and_duplicate_free() {
        let mut rng = Pcg64::new(0xD1D1);
        let mut buf = Vec::new();
        for _case in 0..8 {
            let total = 200 + rng.gen_range_usize(2000);
            let rate = [5.0, 10.0, 25.0, 50.0][rng.gen_range_usize(4)];
            let seed = rng.next_u64();
            for strategy in STRATEGIES {
                let d = Divider::new(strategy, rate, seed, total).unwrap();
                for epoch in 0..3 {
                    for i in 0..total {
                        d.targets(epoch, i, &mut buf);
                        for &s in &buf {
                            assert!(s < d.num_submodels, "target {s} out of bounds");
                        }
                        let mut uniq = buf.clone();
                        uniq.sort_unstable();
                        uniq.dedup();
                        assert_eq!(uniq.len(), buf.len(), "duplicate targets: {buf:?}");
                        if d.strategy == DivideStrategy::EqualPartitioning {
                            assert_eq!(buf.len(), 1, "equal must route to exactly one");
                        }
                    }
                }
            }
        }
    }

    /// Mean routing multiplicity matches the strategy's expectation:
    /// exactly 1 for EqualPartitioning, n·r ≈ 1 for the Bernoulli
    /// strategies — within a 5-sigma tolerance of the binomial std dev.
    #[test]
    fn property_expected_multiplicity_holds() {
        let mut rng = Pcg64::new(0xD1D2);
        let mut buf = Vec::new();
        for _case in 0..6 {
            let total = 2000 + rng.gen_range_usize(4000);
            let rate = [10.0, 20.0, 25.0][rng.gen_range_usize(3)];
            let seed = rng.next_u64();
            for strategy in STRATEGIES {
                let d = Divider::new(strategy, rate, seed, total).unwrap();
                let mut routed = 0usize;
                for i in 0..total {
                    d.targets(0, i, &mut buf);
                    routed += buf.len();
                }
                let mean = routed as f64 / total as f64;
                match d.strategy {
                    DivideStrategy::EqualPartitioning => assert_eq!(routed, total),
                    _ => {
                        // per sentence: Binomial(n, r) with mean n·r and
                        // variance n·r·(1−r); 5σ of the empirical mean
                        let expect = d.num_submodels as f64 * d.rate;
                        let sigma = (d.num_submodels as f64 * d.rate * (1.0 - d.rate)
                            / total as f64)
                            .sqrt();
                        assert!(
                            (mean - expect).abs() < 5.0 * sigma + 1e-9,
                            "multiplicity {mean:.4} vs expected {expect:.4} (σ={sigma:.5})"
                        );
                    }
                }
            }
        }
    }

    /// Shuffle draws a fresh assignment every epoch, but two dividers with
    /// identical seeds replay identical assignments epoch by epoch (and a
    /// different seed diverges).
    #[test]
    fn property_shuffle_epochs_differ_but_seeds_reproduce() {
        let mut rng = Pcg64::new(0xD1D3);
        for _case in 0..5 {
            let total = 1000 + rng.gen_range_usize(2000);
            let seed = rng.next_u64();
            let a = Divider::new(DivideStrategy::Shuffle, 20.0, seed, total).unwrap();
            let b = Divider::new(DivideStrategy::Shuffle, 20.0, seed, total).unwrap();
            let c = Divider::new(DivideStrategy::Shuffle, 20.0, seed ^ 0x5EED, total).unwrap();
            for epoch in 0..3 {
                assert_eq!(
                    collect(&a, epoch),
                    collect(&b, epoch),
                    "same seed must replay the same epoch-{epoch} assignment"
                );
            }
            assert_ne!(collect(&a, 0), collect(&a, 1), "epochs must differ");
            assert_ne!(collect(&a, 1), collect(&a, 2), "epochs must differ");
            assert_ne!(collect(&a, 0), collect(&c, 0), "seeds must decorrelate");
        }
    }
}
