//! The leader: orchestrates the full divide → train → merge → eval run.
//!
//! This is the entry point the CLI, the examples and every bench harness
//! drive. It owns phase timing (the numbers behind Table 4 / Figure 2),
//! constructs the MapReduce topology (mappers route, reducers train
//! backend-resident sub-models), and hands the trained sub-models to the
//! merge phase and the merged consensus to the evaluation harness.
//!
//! Everything is generic over [`Backend`]: the same orchestration runs
//! the native CPU engine (default builds, CI) and the PJRT/XLA bridge
//! (`--features xla` + artifacts) unchanged.

use super::divider::Divider;
use super::mapper::{CorpusSource, SentenceRouter};
use super::reducer::TrainReducer;
use crate::embedding::Embedding;
use crate::eval::report::{evaluate_suite, BenchmarkScore};
use crate::exec::mapreduce::{MapReduce, RunStats};
use crate::gen::benchmarks::Benchmark;
use crate::merge::alir::AlirOptions;
use crate::merge::{merge_models, MergeResult};
use crate::runtime::backend::Backend;
use crate::sgns::config::SgnsConfig;
use crate::sgns::trainer::SubModelTrainer;
use crate::text::corpus::Corpus;
use crate::text::vocab::Vocab;
use crate::util::config::ExperimentConfig;
use crate::util::logging::Timer;
use crate::util::rng::Pcg64;
use crate::info;
use std::sync::Arc;

/// Result of the train phase.
pub struct TrainOutput {
    pub submodels: Vec<Embedding>,
    /// per-sub-model, per-epoch mean loss (the e2e loss curves)
    pub epoch_loss: Vec<Vec<f64>>,
    pub train_secs: f64,
    pub mr_stats: RunStats,
    pub pairs: u64,
    /// pairs emitted by each sub-model's trainer, in sub-model order —
    /// what a multi-process worker for the same sub-model reports in its
    /// artifact meta (the chaos e2e derives crash thresholds from this)
    pub pairs_per_submodel: Vec<u64>,
    pub dispatches: u64,
    /// mean per-reducer device busy time — what a dedicated node per
    /// reducer would see as its train phase (the paper's Table 4 metric)
    pub avg_reducer_busy_secs: f64,
    pub max_reducer_busy_secs: f64,
}

/// Extract the SGNS hyperparameters from the experiment config.
pub fn sgns_config(cfg: &ExperimentConfig) -> SgnsConfig {
    SgnsConfig {
        dim: cfg.dim,
        window: cfg.window,
        negatives: cfg.negatives,
        subsample_t: cfg.subsample_t,
        lr0: cfg.lr0,
        lr_min: cfg.lr_min,
        epochs: cfg.epochs,
        noise_power: 0.75,
    }
}

/// The divider every path of a run must construct: seed decorrelated
/// from model init, sized to the corpus. In-process reducers and
/// multi-process workers calling this with the same `(cfg, corpus_len)`
/// agree on every routing decision — the stateless-coordination property
/// the whole design rests on.
pub fn run_divider(cfg: &ExperimentConfig, corpus_len: usize) -> Result<Divider, String> {
    Divider::new(
        cfg.strategy.clone(),
        cfg.rate_percent,
        cfg.seed ^ 0xD1, // decorrelate from model init
        corpus_len,
    )
}

/// The model-init seed of sub-model `submodel`, derived from the
/// experiment's root seed. Shared by the in-process leader and the
/// multi-process workers so the two paths initialize (and therefore
/// train) identical sub-models.
pub fn submodel_seed(root_seed: u64, submodel: usize) -> u64 {
    Pcg64::new(root_seed).derive(submodel as u64).next_u64()
}

/// The lr-schedule denominator for one sub-model: the calibrated
/// per-epoch pair expectation scaled by the sub-model's expected share of
/// the corpus and the epoch count. Kept as a single expression so the
/// in-process and multi-process paths compute **bitwise** the same value
/// from the same inputs.
pub fn submodel_expected_pairs(
    cfg: &ExperimentConfig,
    per_epoch_pairs: f64,
    divider: &Divider,
    corpus_len: usize,
) -> u64 {
    let submodel_share = divider.expected_per_submodel() / corpus_len.max(1) as f64;
    (per_epoch_pairs * submodel_share * cfg.epochs as f64) as u64
}

/// Divide + train: run `cfg.epochs` MapReduce rounds with one
/// backend-resident trainer per sub-model and return the trained
/// sub-models.
pub fn train_submodels<B: Backend>(
    cfg: &ExperimentConfig,
    corpus: &Corpus,
    vocab: &Vocab,
    backend: &B,
) -> Result<TrainOutput, String> {
    let scfg = sgns_config(cfg);
    let divider = Arc::new(run_divider(cfg, corpus.len())?);
    let n = divider.num_submodels;
    // calibrated pair expectation (subsampling keep-mass × mean dynamic
    // window, see `sgns::schedule`), scaled to each sub-model's expected
    // share of the corpus sentences
    let per_epoch = crate::sgns::schedule::expected_pairs_per_epoch(corpus, vocab, &scfg);
    let expected_pairs = submodel_expected_pairs(cfg, per_epoch, &divider, corpus.len());

    info!(
        "train: {} sub-models (strategy={}, r={}%), {} epochs, expected ~{} pairs each",
        n,
        cfg.strategy.name(),
        cfg.rate_percent,
        cfg.epochs,
        expected_pairs
    );

    let mut reducers = Vec::with_capacity(n);
    for s in 0..n {
        let seed = submodel_seed(cfg.seed, s);
        let trainer = SubModelTrainer::new(backend, vocab, &scfg, expected_pairs, seed)?;
        reducers.push(TrainReducer::new(trainer));
    }

    let timer = Timer::start("train phase");
    let mr = MapReduce {
        num_mappers: cfg.mappers,
        queue_capacity: cfg.queue_capacity,
    };
    let mr_stats = mr.run(
        cfg.epochs,
        &CorpusSource { corpus },
        |epoch, _shard| SentenceRouter::new(Arc::clone(&divider), epoch),
        &mut reducers,
    );
    let train_secs = timer.stop_quiet();

    let min_count = cfg.submodel_min_count();
    let mut submodels = Vec::with_capacity(n);
    let mut epoch_loss = Vec::with_capacity(n);
    let mut pairs = 0;
    let mut pairs_per_submodel = Vec::with_capacity(n);
    let mut dispatches = 0;
    let mut busy = Vec::with_capacity(n);
    for red in reducers {
        if let Some(e) = red.error {
            return Err(format!("reducer failed: {e}"));
        }
        epoch_loss.push(red.epoch_mean_loss.clone());
        pairs += red.trainer.pairs_emitted();
        pairs_per_submodel.push(red.trainer.pairs_emitted());
        dispatches += red.trainer.dispatches();
        busy.push(red.trainer.device_secs);
        submodels.push(red.trainer.into_embedding(min_count)?);
    }
    info!(
        "train done: {:.2}s, {} pairs, {} dispatches, {:.2}s sender-blocked",
        train_secs, pairs, dispatches, mr_stats.send_blocked_secs
    );
    let avg_busy = busy.iter().sum::<f64>() / busy.len().max(1) as f64;
    let max_busy = busy.iter().cloned().fold(0.0, f64::max);
    Ok(TrainOutput {
        submodels,
        epoch_loss,
        train_secs,
        mr_stats,
        pairs,
        pairs_per_submodel,
        dispatches,
        avg_reducer_busy_secs: avg_busy,
        max_reducer_busy_secs: max_busy,
    })
}

/// Full-pipeline report: everything the paper's tables need for one row.
pub struct PipelineReport {
    pub scores: Vec<BenchmarkScore>,
    pub train: TrainOutput,
    pub merge_secs: f64,
    pub eval_secs: f64,
    pub merged_vocab: usize,
    pub alir_rounds: usize,
    pub alir_displacement: Vec<f64>,
}

/// The merge → eval tail shared by the in-process pipeline and the
/// multi-process coordinator: whatever trained the sub-models — reducer
/// threads or collected worker artifacts (possibly fewer than requested,
/// when workers died) — the consensus is built and scored the same way.
pub struct MergeEvalOutput {
    pub merged: MergeResult,
    pub scores: Vec<BenchmarkScore>,
    pub eval_secs: f64,
}

/// Merge the trained sub-models and evaluate the consensus — the tail
/// every training path funnels into. See [`MergeEvalOutput`].
pub fn merge_and_eval(
    cfg: &ExperimentConfig,
    submodels: &[Embedding],
    suite: &[Benchmark],
) -> MergeEvalOutput {
    let merged = merge_trained(cfg, submodels);
    let timer = Timer::start("eval phase");
    let scores = evaluate_suite(&merged.embedding, suite, cfg.seed);
    let eval_secs = timer.stop_quiet();
    let reg = crate::obs::metrics::global();
    if reg.enabled() {
        reg.gauge("merge_secs").set(merged.seconds);
        reg.gauge("eval_secs").set(eval_secs);
        reg.counter("merged_submodels").add(submodels.len() as u64);
    }
    MergeEvalOutput {
        merged,
        scores,
        eval_secs,
    }
}

/// divide → train → merge → eval with the experiment's configured
/// strategy/rate/merge method.
pub fn run_pipeline<B: Backend>(
    cfg: &ExperimentConfig,
    corpus: &Corpus,
    vocab: &Vocab,
    suite: &[Benchmark],
    backend: &B,
) -> Result<PipelineReport, String> {
    let train = train_submodels(cfg, corpus, vocab, backend)?;
    let tail = merge_and_eval(cfg, &train.submodels, suite);
    Ok(PipelineReport {
        scores: tail.scores,
        merged_vocab: tail.merged.embedding.present_count(),
        merge_secs: tail.merged.seconds,
        alir_rounds: tail.merged.alir_rounds,
        alir_displacement: tail.merged.alir_displacement,
        eval_secs: tail.eval_secs,
        train,
    })
}

/// Merge already-trained sub-models with the experiment's merge settings.
pub fn merge_trained(cfg: &ExperimentConfig, submodels: &[Embedding]) -> MergeResult {
    let alir_opts = AlirOptions {
        init: crate::merge::alir::AlirInit::Pca,
        max_rounds: cfg.alir_rounds,
        tol: cfg.alir_tol,
    };
    merge_models(submodels, &cfg.merge, &alir_opts, cfg.seed ^ 0x4D)
}
