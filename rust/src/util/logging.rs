//! Leveled stderr logging + scoped wall-clock timers.
//!
//! The coordinator reports phase timings (divide/train/merge/eval) through
//! [`Timer`]; benches and examples read the same numbers the paper's
//! Table 4 reports.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_env() {
    if let Ok(v) = std::env::var("DW2V_LOG") {
        let lvl = match v.to_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            _ => Level::Info,
        };
        set_level(lvl);
    }
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

/// Scoped wall-clock timer. `stop()` (or `Drop` with logging) returns the
/// elapsed seconds; phases aggregate these into the run report.
pub struct Timer {
    label: String,
    start: Instant,
}

impl Timer {
    pub fn start(label: &str) -> Self {
        Self {
            label: label.to_string(),
            start: Instant::now(),
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Stop and log at info level; returns elapsed seconds.
    pub fn stop(self) -> f64 {
        let secs = self.elapsed_secs();
        log(
            Level::Info,
            "timer",
            &format!("{} took {:.3}s", self.label, secs),
        );
        secs
    }

    /// Stop silently; returns elapsed seconds.
    pub fn stop_quiet(self) -> f64 {
        self.elapsed_secs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_time() {
        let t = Timer::start("unit");
        std::thread::sleep(std::time::Duration::from_millis(15));
        let secs = t.stop_quiet();
        assert!(secs >= 0.014, "elapsed={secs}");
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
