//! Leveled stderr logging + scoped wall-clock timers.
//!
//! The coordinator reports phase timings (divide/train/merge/eval) through
//! [`Timer`]; benches and examples read the same numbers the paper's
//! Table 4 reports.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    // lint-allow: relaxed-ordering independent filter flag; no data is published under it
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Parse one `DW2V_LOG` value. Garbage is a loud error naming the
/// variable (same contract as `DW2V_BEACON_INTERVAL_MS` /
/// `DW2V_FEED`) — a typo'd `DW2V_LOG=dbug` must not silently run at
/// info and bury the debug output someone asked for.
pub fn parse_level(v: &str) -> Result<Level, String> {
    match v.to_lowercase().as_str() {
        "error" => Ok(Level::Error),
        "warn" => Ok(Level::Warn),
        "info" => Ok(Level::Info),
        "debug" => Ok(Level::Debug),
        other => Err(format!(
            "DW2V_LOG={other:?} is not a log level (use error|warn|info|debug)"
        )),
    }
}

/// Apply `DW2V_LOG` from the environment (via `util::env`, the one
/// place that reads `DW2V_*` knobs). Unset leaves the default (info);
/// an unknown value is an error the caller must surface at startup.
pub fn level_from_env() -> Result<(), String> {
    if let Some(level) = crate::util::env::log_level()? {
        set_level(level);
    }
    Ok(())
}

// The process role, stamped into every log line so the interleaved
// stderr of a supervised fleet stays attributable. Set once at startup
// (coordinator leaves it unset; a worker sets `worker s=N`).
static ROLE: OnceLock<String> = OnceLock::new();

/// Tag every subsequent log line with `role` (first caller wins).
pub fn set_role(role: &str) {
    let _ = ROLE.set(role.to_string());
}

pub fn enabled(level: Level) -> bool {
    // lint-allow: relaxed-ordering independent filter flag; no data is published under it
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        match ROLE.get() {
            Some(role) => eprintln!("[{tag}][{role}] {module}: {msg}"),
            None => eprintln!("[{tag}] {module}: {msg}"),
        }
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

/// Scoped wall-clock timer. `stop()` (or `Drop` with logging) returns the
/// elapsed seconds; phases aggregate these into the run report.
pub struct Timer {
    label: String,
    start: Instant,
}

impl Timer {
    pub fn start(label: &str) -> Self {
        Self {
            label: label.to_string(),
            start: Instant::now(),
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Stop and log at info level; returns elapsed seconds.
    pub fn stop(self) -> f64 {
        let secs = self.elapsed_secs();
        log(
            Level::Info,
            "timer",
            &format!("{} took {:.3}s", self.label, secs),
        );
        secs
    }

    /// Stop silently; returns elapsed seconds.
    pub fn stop_quiet(self) -> f64 {
        self.elapsed_secs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_time() {
        let t = Timer::start("unit");
        std::thread::sleep(std::time::Duration::from_millis(15));
        let secs = t.stop_quiet();
        assert!(secs >= 0.014, "elapsed={secs}");
    }

    #[test]
    fn level_parse_is_loud_on_garbage() {
        assert_eq!(parse_level("error").unwrap(), Level::Error);
        assert_eq!(parse_level("WARN").unwrap(), Level::Warn);
        assert_eq!(parse_level("Info").unwrap(), Level::Info);
        assert_eq!(parse_level("debug").unwrap(), Level::Debug);
        for garbage in ["dbug", "verbose", "2", ""] {
            let err = parse_level(garbage).unwrap_err();
            assert!(err.contains("DW2V_LOG"), "{err}");
            assert!(err.contains("error|warn|info|debug"), "{err}");
        }
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
