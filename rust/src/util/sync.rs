//! Atomics shim: std by default, loom's model-checked atomics when the
//! crate is built with `RUSTFLAGS="--cfg loom"`.
//!
//! Only the atomic types (and `yield_now`) are switched. `Arc`, `Mutex`
//! and `OnceLock` stay `std` everywhere: the registry hands `Arc`
//! handles across module boundaries (e.g. `Registry::counter` →
//! `serve`/`engine`), and swapping `Arc` under loom would change those
//! public types crate-wide for no modeling benefit — loom tracks the
//! atomics themselves regardless of what shares them.
//!
//! The `loom` dependency is intentionally **not** in the checked-in
//! manifest (builds must resolve offline); the CI loom job appends a
//! `[target.'cfg(loom)'.dependencies]` section before running, which is
//! the loom-documented setup. Under a normal build every `cfg(loom)`
//! item here compiles away.

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

#[cfg(not(loom))]
pub use std::thread::yield_now;

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

#[cfg(loom)]
pub use loom::thread::yield_now;
