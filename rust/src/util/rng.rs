//! Deterministic pseudo-random number generation.
//!
//! crates.io is unavailable in this environment, so we carry our own
//! generators: [`SplitMix64`] for seeding/stream derivation and [`Pcg64`]
//! (PCG-XSL-RR 128/64) as the workhorse. Determinism across the whole
//! system matters: the paper's `RandomSampling` divider must replay the
//! *same* sample every epoch, which we implement by re-seeding a derived
//! stream (see `coordinator::divider`), and experiments must be exactly
//! reproducible from a single seed.

/// SplitMix64 — tiny, fast, and the canonical way to expand one u64 seed
/// into many independent seeds (Steele et al., "Fast Splittable PRNGs").
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
/// Passes BigCrush; supports cheap independent streams via the odd
/// increment parameter.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // must be odd
}

const PCG_MUL: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Seed a generator; `stream` selects one of 2^127 independent streams.
    pub fn new_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ 0xA02B_DBF7_BB3C_0A7A);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let mut sm2 = SplitMix64::new(stream ^ 0x6A09_E667_F3BC_C909);
        let i0 = sm2.next_u64() as u128;
        let i1 = sm2.next_u64() as u128;
        let mut rng = Self {
            state: (s0 << 64) | s1,
            inc: (((i0 << 64) | i1) << 1) | 1,
        };
        // decorrelate from the seeding structure
        rng.next_u64();
        rng.next_u64();
        rng
    }

    pub fn new(seed: u64) -> Self {
        Self::new_stream(seed, 0)
    }

    /// Derive a child generator for a named sub-stream. Used to give every
    /// (worker, epoch, purpose) tuple its own independent stream.
    pub fn derive(&self, tag: u64) -> Self {
        // combine our own stream identity with the tag through SplitMix
        let mut sm = SplitMix64::new((self.inc as u64) ^ tag.rotate_left(17));
        let seed = sm.next_u64() ^ (self.state as u64);
        Self::new_stream(seed, sm.next_u64() ^ tag)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MUL).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn gen_range_usize(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; this is nowhere near a hot path).
    pub fn gen_gauss(&mut self) -> f64 {
        loop {
            let u1 = self.gen_f64();
            if u1 > 1e-12 {
                let u2 = self.gen_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (floyd's algorithm for small
    /// k, shuffle prefix otherwise).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range_usize(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_sequence_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg64::new_stream(1, 0);
        let mut b = Pcg64::new_stream(1, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "streams should be effectively independent");
    }

    #[test]
    fn pcg_is_reproducible() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn derive_changes_stream_but_stays_deterministic() {
        let root = Pcg64::new(3);
        let mut c1 = root.derive(10);
        let mut c2 = root.derive(10);
        let mut c3 = root.derive(11);
        let first = c1.next_u64();
        assert_eq!(first, c2.next_u64());
        assert_ne!(first, c3.next_u64());
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Pcg64::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_in_unit_interval_and_mean_half() {
        let mut rng = Pcg64::new(11);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut rng = Pcg64::new(13);
        let n = 40_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.gen_gauss();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Pcg64::new(19);
        for (n, k) in [(100, 5), (10, 10), (1000, 999), (1, 1)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Pcg64::new(23);
        let hits = (0..50_000).filter(|_| rng.gen_bool(0.1)).count();
        let rate = hits as f64 / 50_000.0;
        assert!((rate - 0.1).abs() < 0.01, "rate={rate}");
    }
}
