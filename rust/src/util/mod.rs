//! Cross-cutting substrates: RNG, JSON, CLI, logging, configuration.
pub mod cli;
pub mod config;
pub mod json;
pub mod logging;
pub mod rng;
