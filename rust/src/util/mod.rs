//! Cross-cutting substrates: RNG, JSON, CLI, logging, configuration,
//! and the `DW2V_*` environment-knob registry.
pub mod cli;
pub mod config;
pub mod env;
pub mod json;
pub mod logging;
pub mod rng;
pub mod sync;
