//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Used for the AOT `manifest.json`, experiment configs, and machine-
//! readable bench reports. Supports the full JSON grammar except `\u`
//! surrogate pairs outside the BMP are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so that
/// serialization is deterministic — bench reports diff cleanly.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(lvl) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(lvl + 1));
                        v.write(out, Some(lvl + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let (Some(lvl), false) = (indent, a.is_empty()) {
                    out.push('\n');
                    out.push_str(&"  ".repeat(lvl));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(lvl) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(lvl + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(lvl + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let (Some(lvl), false) = (indent, o.is_empty()) {
                    out.push('\n');
                    out.push_str(&"  ".repeat(lvl));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report building.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Largest integer a JSON number can carry exactly (the f64 mantissa).
pub const MAX_SAFE_INT: u64 = 1 << 53;

/// An integer as a JSON number, checked against the f64 precision
/// ceiling. Ids, sizes and config knobs belong here; counters that can
/// realistically pass 2^53 (token/pair totals) must use [`u64s`]
/// instead — `cargo xtask lint` (rule `json-int-precision`) rejects the
/// unchecked `num(x as f64)` spelling everywhere outside this module.
pub fn inum<T>(v: T) -> Json
where
    u64: TryFrom<T>,
{
    let v = u64::try_from(v)
        .unwrap_or_else(|_| panic!("inum: negative integer cannot enter JSON as a count"));
    assert!(
        v <= MAX_SAFE_INT,
        "inum({v}): past the 2^53 f64 ceiling — serialize with u64s() instead"
    );
    Json::Num(v as f64)
}

/// An f32 field as a JSON number — the f64 widening is exact, so this
/// is the one integer-free cast the precision rule blesses by name.
pub fn fnum(v: f32) -> Json {
    Json::Num(f64::from(v))
}

/// A u64 as a decimal-string JSON value — the repo convention for
/// counters that would lose precision as f64 above 2^53.
pub fn u64s(n: u64) -> Json {
    s(&n.to_string())
}

/// Read a u64 back from either encoding (decimal string or number).
pub fn json_u64(v: &Json) -> Option<u64> {
    match v {
        Json::Str(text) => text.parse::<u64>().ok(),
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
        _ => None,
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            while self.pos < self.bytes.len()
                && self.bytes[self.pos] != b'"'
                && self.bytes[self.pos] != b'\\'
            {
                self.pos += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| self.err("invalid utf8"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => return Err(self.err("unterminated string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|b| b.is_ascii_digit() || matches!(b, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_via_display() {
        let orig = obj(vec![
            ("name", s("tab\t\"quote\"")),
            ("xs", arr(vec![num(1.0), num(2.5), Json::Null])),
            ("flag", Json::Bool(false)),
        ]);
        let parsed = Json::parse(&orig.to_string()).unwrap();
        assert_eq!(parsed, orig);
        let parsed_pretty = Json::parse(&orig.to_string_pretty()).unwrap();
        assert_eq!(parsed_pretty, orig);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""éA""#).unwrap(),
            Json::Str("éA".into())
        );
    }

    #[test]
    fn integer_formatting_has_no_fraction() {
        assert_eq!(num(3.0).to_string(), "3");
        assert_eq!(num(3.25).to_string(), "3.25");
    }

    #[test]
    fn inum_accepts_every_unsigned_width_and_checks_the_ceiling() {
        assert_eq!(inum(7u32).to_string(), "7");
        assert_eq!(inum(7u64).to_string(), "7");
        assert_eq!(inum(7usize).to_string(), "7");
        assert_eq!(inum(7u128).to_string(), "7");
        assert_eq!(inum(MAX_SAFE_INT).to_string(), MAX_SAFE_INT.to_string());
        assert!(std::panic::catch_unwind(|| inum(MAX_SAFE_INT + 1)).is_err());
        assert!(std::panic::catch_unwind(|| inum(-1i64)).is_err());
    }

    #[test]
    fn fnum_widens_exactly() {
        assert_eq!(fnum(0.25f32), Json::Num(0.25));
        assert_eq!(fnum(1e-3f32).as_f64().unwrap() as f32, 1e-3f32);
    }

    #[test]
    fn u64s_roundtrips_past_the_f64_ceiling() {
        let big = (1u64 << 60) + 1;
        assert_eq!(json_u64(&u64s(big)), Some(big));
        assert_eq!(json_u64(&num(5.0)), Some(5), "legacy numeric encoding reads back");
        assert_eq!(json_u64(&num(5.5)), None);
        assert_eq!(json_u64(&s("nope")), None);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 5, "f": 5.5}"#).unwrap();
        assert_eq!(v.get("n").as_usize(), Some(5));
        assert_eq!(v.get("f").as_usize(), None);
        assert_eq!(v.get("missing").as_str(), None);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"version": 1, "configs": [{"name": "v64", "vocab": 64,
            "files": {"train": "t.hlo.txt"}}]}"#;
        let v = Json::parse(text).unwrap();
        let cfgs = v.get("configs").as_arr().unwrap();
        assert_eq!(cfgs[0].get("vocab").as_usize(), Some(64));
        assert_eq!(cfgs[0].get("files").get("train").as_str(), Some("t.hlo.txt"));
    }
}
