//! Every `DW2V_*` environment knob, in one place.
//!
//! The env surface is part of the coordinator↔worker contract — workers
//! inherit these variables from the coordinator that spawned them — so
//! the names, the parse rules, and the failure behavior live here
//! instead of being scattered over the call sites. The rules:
//!
//! * **Unset means default.** Every knob has a behavior when absent.
//! * **Garbage is loud.** A set-but-unparsable knob is an error that
//!   names the variable and the offending value, never a silent
//!   fallback — a typo'd knob must not quietly run with defaults.
//! * Call sites read knobs through the helpers below, not through
//!   `std::env::var` with a string literal.
//!
//! The full table (also printed by `dw2v --help`):
//!
//! | variable | meaning |
//! |----------|---------|
//! | `DW2V_LOG` | log level: `error` \| `warn` \| `info` \| `debug` |
//! | `DW2V_FAULT` | fault-injection spec parsed by each worker (see `coordinator::supervisor::FaultSpec`) |
//! | `DW2V_FEED` | `1` = workers follow a growing shard dir (overlap mode), `0`/unset = snapshot |
//! | `DW2V_BEACON_INTERVAL_MS` | worker heartbeat publish interval, milliseconds (default 250) |
//! | `DW2V_WORKER_STARTUP_SLEEP_MS` | test hook: worker sleeps this long before training |
//! | `DW2V_INGEST_SHARD_DELAY_MS` | test hook: overlap ingest sleeps this long before each shard |
//! | `DW2V_WORKER_EXE` | dw2v binary for spawned workers (tests point this at the build) |
//! | `DW2V_BENCH_DIR` | bench harnesses append trajectory JSONL under this directory |
//! | `DW2V_BENCH_SCALE` | `full` = run benches at paper scale, unset = smoke scale |

use crate::util::logging::{parse_level, Level};

/// `DW2V_LOG` — log level (`error`|`warn`|`info`|`debug`).
pub const LOG: &str = "DW2V_LOG";
/// `DW2V_FAULT` — fault-injection spec, parsed by each worker at startup.
pub const FAULT: &str = "DW2V_FAULT";
/// `DW2V_FEED` — `1` = follow a growing shard dir, `0`/unset = snapshot.
pub const FEED: &str = "DW2V_FEED";
/// `DW2V_BEACON_INTERVAL_MS` — worker heartbeat interval (default 250).
pub const BEACON_INTERVAL_MS: &str = "DW2V_BEACON_INTERVAL_MS";
/// `DW2V_WORKER_STARTUP_SLEEP_MS` — test hook: pre-training sleep.
pub const WORKER_STARTUP_SLEEP_MS: &str = "DW2V_WORKER_STARTUP_SLEEP_MS";
/// `DW2V_INGEST_SHARD_DELAY_MS` — test hook: per-shard ingest delay.
pub const INGEST_SHARD_DELAY_MS: &str = "DW2V_INGEST_SHARD_DELAY_MS";
/// `DW2V_WORKER_EXE` — dw2v binary to spawn for workers.
pub const WORKER_EXE: &str = "DW2V_WORKER_EXE";
/// `DW2V_BENCH_DIR` — where bench harnesses append trajectory rows.
pub const BENCH_DIR: &str = "DW2V_BENCH_DIR";
/// `DW2V_BENCH_SCALE` — `full` = paper scale, anything else = smoke.
pub const BENCH_SCALE: &str = "DW2V_BENCH_SCALE";

/// `(name, one-line meaning)` for every knob — the source of the table
/// printed by `dw2v --help` (see [`knob_table`]).
pub const KNOBS: &[(&str, &str)] = &[
    (LOG, "log level: error | warn | info | debug"),
    (FAULT, "fault-injection spec parsed by each worker at startup"),
    (FEED, "1 = workers follow a growing shard dir (overlap), 0/unset = snapshot"),
    (BEACON_INTERVAL_MS, "worker heartbeat publish interval in ms (default 250)"),
    (WORKER_STARTUP_SLEEP_MS, "test hook: worker sleeps this long before training"),
    (INGEST_SHARD_DELAY_MS, "test hook: overlap ingest sleeps this long per shard"),
    (WORKER_EXE, "dw2v binary to spawn for train-worker processes"),
    (BENCH_DIR, "bench harnesses append trajectory JSONL under this directory"),
    (BENCH_SCALE, "'full' = paper-scale benches, unset = smoke scale"),
];

/// The knob table as aligned text, for `--help` output.
pub fn knob_table() -> String {
    let width = KNOBS.iter().map(|(n, _)| n.len()).max().unwrap_or(0);
    let mut out = String::new();
    for (name, about) in KNOBS {
        out.push_str(&format!("  {name:<width$}  {about}\n"));
    }
    // drop the trailing newline so callers embed it like any other block
    out.pop();
    out
}

fn var(name: &str) -> Option<String> {
    std::env::var(name).ok()
}

/// Parse the `DW2V_FEED` value: absent/`0` = snapshot, `1` = feed mode.
/// Anything else is a loud error — a typo'd feed flag silently training
/// on a partial snapshot would be miserable to debug.
fn parse_feed_mode(raw: Option<&str>) -> Result<bool, String> {
    match raw.map(str::trim) {
        None | Some("") | Some("0") => Ok(false),
        Some("1") => Ok(true),
        Some(v) => Err(format!("{FEED}: expected 0 or 1, got '{v}'")),
    }
}

/// Parse the `DW2V_BEACON_INTERVAL_MS` value (absent = 250 ms default).
fn parse_beacon_interval(raw: Option<&str>) -> Result<u64, String> {
    match raw.map(str::trim) {
        None => Ok(250),
        Some(v) => v.parse::<u64>().map_err(|_| {
            format!("{BEACON_INTERVAL_MS}: '{v}' is not a whole number of milliseconds")
        }),
    }
}

/// Parse an optional whole-millisecond knob: unset/blank = `None`,
/// garbage = a loud error naming the variable.
fn parse_opt_ms(name: &str, raw: Option<&str>) -> Result<Option<u64>, String> {
    match raw {
        None => Ok(None),
        Some(v) if v.trim().is_empty() => Ok(None),
        Some(v) => v
            .trim()
            .parse::<u64>()
            .map(Some)
            .map_err(|_| format!("{name}: '{v}' is not a whole number of milliseconds")),
    }
}

/// `DW2V_FEED` from the environment.
pub fn feed_mode() -> Result<bool, String> {
    parse_feed_mode(var(FEED).as_deref())
}

/// `DW2V_BEACON_INTERVAL_MS` from the environment (default 250).
pub fn beacon_interval_ms() -> Result<u64, String> {
    parse_beacon_interval(var(BEACON_INTERVAL_MS).as_deref())
}

/// `DW2V_FAULT` raw spec text, if set (parsing is `FaultSpec::parse`'s
/// job — the grammar lives with the fault machinery).
pub fn fault_spec() -> Option<String> {
    var(FAULT)
}

/// `DW2V_WORKER_STARTUP_SLEEP_MS` — `None` when unset/blank, loud on
/// garbage (a chaos test that typos its delay must fail, not silently
/// skip the window it meant to open).
pub fn worker_startup_sleep_ms() -> Result<Option<u64>, String> {
    parse_opt_ms(WORKER_STARTUP_SLEEP_MS, var(WORKER_STARTUP_SLEEP_MS).as_deref())
}

/// `DW2V_INGEST_SHARD_DELAY_MS` — `None` when unset/blank, loud on garbage.
pub fn ingest_shard_delay_ms() -> Result<Option<u64>, String> {
    parse_opt_ms(INGEST_SHARD_DELAY_MS, var(INGEST_SHARD_DELAY_MS).as_deref())
}

/// `DW2V_WORKER_EXE`, if set (existence is checked at the call site,
/// where the error can say what the path was supposed to be).
pub fn worker_exe() -> Option<String> {
    var(WORKER_EXE)
}

/// `DW2V_BENCH_DIR`, if set to a non-blank path.
pub fn bench_dir() -> Option<String> {
    match var(BENCH_DIR) {
        Some(d) if !d.trim().is_empty() => Some(d),
        _ => None,
    }
}

/// `DW2V_BENCH_SCALE` — true when the benches should run at paper scale.
pub fn bench_full_scale() -> bool {
    matches!(var(BENCH_SCALE).as_deref(), Some("full"))
}

/// `DW2V_LOG` — `None` when unset, the parsed [`Level`] when valid, a
/// loud error otherwise.
pub fn log_level() -> Result<Option<Level>, String> {
    match var(LOG) {
        None => Ok(None),
        Some(text) => parse_level(&text).map(Some),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beacon_interval_parse_is_loud_on_garbage() {
        // unset → documented default; well-formed values parse
        assert_eq!(parse_beacon_interval(None), Ok(250));
        assert_eq!(parse_beacon_interval(Some("10")), Ok(10));
        assert_eq!(parse_beacon_interval(Some(" 500 ")), Ok(500));
        // malformed values must be startup errors naming the variable,
        // never a silent fall-back to 250ms
        for bad in ["fast", "250ms", "", "-5", "2.5"] {
            let err = parse_beacon_interval(Some(bad)).unwrap_err();
            assert!(
                err.contains("DW2V_BEACON_INTERVAL_MS"),
                "'{bad}' must fail loudly, got: {err}"
            );
        }
    }

    #[test]
    fn feed_flag_parse_is_loud_on_garbage() {
        assert_eq!(parse_feed_mode(None), Ok(false));
        assert_eq!(parse_feed_mode(Some("0")), Ok(false));
        assert_eq!(parse_feed_mode(Some("")), Ok(false));
        assert_eq!(parse_feed_mode(Some("1")), Ok(true));
        for bad in ["yes", "true", "2"] {
            assert!(parse_feed_mode(Some(bad)).is_err(), "should reject: {bad}");
        }
        let err = parse_feed_mode(Some("yes")).unwrap_err();
        assert!(err.contains("DW2V_FEED"), "{err}");
    }

    #[test]
    fn optional_ms_knobs_are_loud_on_garbage_and_none_on_blank() {
        assert_eq!(parse_opt_ms(WORKER_STARTUP_SLEEP_MS, None).unwrap(), None);
        assert_eq!(parse_opt_ms(WORKER_STARTUP_SLEEP_MS, Some("  ")).unwrap(), None);
        assert_eq!(parse_opt_ms(WORKER_STARTUP_SLEEP_MS, Some("1500")).unwrap(), Some(1500));
        let err = parse_opt_ms(INGEST_SHARD_DELAY_MS, Some("soon")).unwrap_err();
        assert!(err.contains("DW2V_INGEST_SHARD_DELAY_MS"), "{err}");
        assert!(err.contains("soon"), "{err}");
        assert!(err.contains("whole number of milliseconds"), "{err}");
    }

    #[test]
    fn knob_table_names_every_variable() {
        let table = knob_table();
        for (name, _) in KNOBS {
            assert!(table.contains(name), "knob table is missing {name}");
        }
        assert!(!table.ends_with('\n'));
    }
}
