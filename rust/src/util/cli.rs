//! Declarative command-line flag parsing (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments; generates `--help` text from the declarations.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

#[derive(Clone)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// A parsed argument set for one (sub)command.
#[derive(Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<u64>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: expected number, got '{v}'"))),
        }
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Builder for one command's flag set.
pub struct Command {
    name: String,
    about: String,
    flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Self {
            name: name.to_string(),
            about: about.to_string(),
            flags: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: default.map(|s| s.to_string()),
            is_bool: false,
        });
        self
    }

    pub fn bool_flag(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nflags:\n", self.name, self.about);
        for f in &self.flags {
            let default = f
                .default
                .as_ref()
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            let kind = if f.is_bool { "" } else { " <value>" };
            out.push_str(&format!("  --{}{kind}\t{}{default}\n", f.name, f.help));
        }
        out
    }

    /// Parse raw argv (without the program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut values = BTreeMap::new();
        let mut bools = BTreeMap::new();
        for f in &self.flags {
            if let Some(d) = &f.default {
                values.insert(f.name.clone(), d.clone());
            }
        }
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                return Err(CliError(self.usage()));
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError(format!("unknown flag --{name}\n\n{}", self.usage())))?;
                if spec.is_bool {
                    if inline.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    bools.insert(name, true);
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                        }
                    };
                    values.insert(name, value);
                }
            } else {
                positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(Args {
            values,
            bools,
            positional,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("train", "train things")
            .flag("epochs", Some("3"), "number of epochs")
            .flag("out", None, "output path")
            .bool_flag("verbose", "chatty mode")
    }

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(a.get_usize("epochs").unwrap(), Some(3));
        assert_eq!(a.get("out"), None);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn parses_space_and_equals_forms() {
        let a = cmd()
            .parse(&argv(&["--epochs", "7", "--out=/tmp/x", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("epochs").unwrap(), Some(7));
        assert_eq!(a.get("out"), Some("/tmp/x"));
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn rejects_unknown_and_bad_types() {
        assert!(cmd().parse(&argv(&["--nope", "1"])).is_err());
        let a = cmd().parse(&argv(&["--epochs", "abc"])).unwrap();
        assert!(a.get_usize("epochs").is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(cmd().parse(&argv(&["--out"])).is_err());
    }

    #[test]
    fn help_lists_flags() {
        let err = cmd().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.0.contains("--epochs"));
        assert!(err.0.contains("default: 3"));
    }
}
