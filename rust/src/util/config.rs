//! Experiment configuration: one flat struct covering every phase knob,
//! loadable from JSON with CLI overrides. This is the single source of
//! truth an experiment run is reproducible from (together with `seed`).

use super::json::{fnum, inum, num, obj, s, Json};

#[derive(Clone, Debug, PartialEq)]
pub enum DivideStrategy {
    /// Sequential split into equal contiguous chunks (paper: EQUAL PARTITIONING).
    EqualPartitioning,
    /// Fixed per-sub-corpus random sample, identical across epochs.
    RandomSampling,
    /// Fresh random sample per epoch (the paper's Shuffle contribution).
    Shuffle,
}

impl DivideStrategy {
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "equal" | "equal_partitioning" => Some(Self::EqualPartitioning),
            "random" | "random_sampling" => Some(Self::RandomSampling),
            "shuffle" => Some(Self::Shuffle),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::EqualPartitioning => "equal",
            Self::RandomSampling => "random",
            Self::Shuffle => "shuffle",
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum MergeMethod {
    Concat,
    Pca,
    AlirRand,
    AlirPca,
    /// Use a single sub-model unmerged (paper's SINGLE MODEL row).
    Single,
}

impl MergeMethod {
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "concat" => Some(Self::Concat),
            "pca" => Some(Self::Pca),
            "alir_rand" | "alir-rand" => Some(Self::AlirRand),
            "alir_pca" | "alir-pca" | "alir" => Some(Self::AlirPca),
            "single" => Some(Self::Single),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Concat => "concat",
            Self::Pca => "pca",
            Self::AlirRand => "alir_rand",
            Self::AlirPca => "alir_pca",
            Self::Single => "single",
        }
    }
}

/// Which compute backend executes the SGNS macro-batch protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum BackendKind {
    /// Prefer the PJRT/XLA artifacts when loadable, else fall back to the
    /// pure-rust native backend (the default: runs everywhere).
    Auto,
    /// Pure-rust CPU backend on the shared vectorized kernels.
    Native,
    /// PJRT/XLA AOT artifacts only (requires `--features xla` + artifacts).
    Xla,
}

impl BackendKind {
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "auto" => Some(Self::Auto),
            "native" | "cpu" => Some(Self::Native),
            "xla" | "pjrt" => Some(Self::Xla),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Native => "native",
            Self::Xla => "xla",
        }
    }
}

/// Validate a sampling rate `r%`. The number of sub-models is
/// `round(100/r)`, so anything outside `(0, 100]` is nonsense: `0`
/// (or any non-finite value) makes the division blow up — before this
/// guard, `(100.0 / 0.0).round() as usize` saturated to `usize::MAX`
/// and the reducer vec allocation aborted the process — and negative or
/// `> 100` rates silently produce Bernoulli probabilities outside
/// `[0, 1]`.
pub fn validate_rate_percent(rate_percent: f64) -> Result<(), String> {
    if !rate_percent.is_finite() || rate_percent <= 0.0 || rate_percent > 100.0 {
        return Err(format!(
            "rate_percent must be in (0, 100], got {rate_percent}"
        ));
    }
    Ok(())
}

/// Full experiment configuration. Defaults reproduce the quickstart run.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub seed: u64,

    // -- synthetic corpus ---------------------------------------------------
    pub sentences: usize,
    pub vocab: usize,
    pub clusters: usize,
    pub truth_dim: usize,
    pub zipf_exponent: f64,
    pub avg_sentence_len: usize,

    // -- SGNS hyperparameters ----------------------------------------------
    pub dim: usize,
    pub window: usize,
    pub negatives: usize,
    pub subsample_t: f64,
    pub lr0: f32,
    pub lr_min: f32,
    pub epochs: usize,
    pub min_count_base: f64, // per-sub-model threshold = min_count_base / n_models

    // -- divide phase --------------------------------------------------------
    pub strategy: DivideStrategy,
    pub rate_percent: f64, // r% — number of sub-models = 100/r

    // -- merge phase ---------------------------------------------------------
    pub merge: MergeMethod,
    pub alir_rounds: usize,
    pub alir_tol: f64,

    // -- execution shape ------------------------------------------------------
    pub mappers: usize,
    pub queue_capacity: usize,
    /// compute backend for trainers (auto = xla when loadable, else native)
    pub backend: BackendKind,
    pub artifact_dir: String,
    pub trainer_batch: usize,
    pub trainer_steps: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            seed: 42,
            sentences: 20_000,
            vocab: 2000,
            clusters: 40,
            truth_dim: 16,
            zipf_exponent: 1.0,
            avg_sentence_len: 18,
            dim: 32,
            window: 5,
            negatives: 5,
            subsample_t: 1e-3,
            lr0: 0.05,
            lr_min: 0.0001,
            epochs: 3,
            min_count_base: 100.0,
            strategy: DivideStrategy::Shuffle,
            rate_percent: 10.0,
            merge: MergeMethod::AlirPca,
            alir_rounds: 3,
            alir_tol: 1e-4,
            mappers: 2,
            queue_capacity: 128,
            backend: BackendKind::Auto,
            artifact_dir: "artifacts".to_string(),
            trainer_batch: 64,
            trainer_steps: 4,
        }
    }
}

impl ExperimentConfig {
    /// Number of sub-models implied by the sampling rate.
    pub fn num_submodels(&self) -> usize {
        ((100.0 / self.rate_percent).round() as usize).max(1)
    }

    /// Per-sub-model vocabulary threshold (paper §4.2: 100/k).
    pub fn submodel_min_count(&self) -> u64 {
        (self.min_count_base / self.num_submodels() as f64).ceil() as u64
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("seed", inum(self.seed)),
            ("sentences", inum(self.sentences)),
            ("vocab", inum(self.vocab)),
            ("clusters", inum(self.clusters)),
            ("truth_dim", inum(self.truth_dim)),
            ("zipf_exponent", num(self.zipf_exponent)),
            ("avg_sentence_len", inum(self.avg_sentence_len)),
            ("dim", inum(self.dim)),
            ("window", inum(self.window)),
            ("negatives", inum(self.negatives)),
            ("subsample_t", num(self.subsample_t)),
            ("lr0", fnum(self.lr0)),
            ("lr_min", fnum(self.lr_min)),
            ("epochs", inum(self.epochs)),
            ("min_count_base", num(self.min_count_base)),
            ("strategy", s(self.strategy.name())),
            ("rate_percent", num(self.rate_percent)),
            ("merge", s(self.merge.name())),
            ("alir_rounds", inum(self.alir_rounds)),
            ("alir_tol", num(self.alir_tol)),
            ("mappers", inum(self.mappers)),
            ("queue_capacity", inum(self.queue_capacity)),
            ("backend", s(self.backend.name())),
            ("artifact_dir", s(&self.artifact_dir)),
            ("trainer_batch", inum(self.trainer_batch)),
            ("trainer_steps", inum(self.trainer_steps)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let mut cfg = Self::default();
        let o = j.as_obj().ok_or("config must be a JSON object")?;
        for (key, val) in o {
            cfg.apply(key, &value_to_string(val))?;
        }
        Ok(cfg)
    }

    /// Apply one `key=value` override (CLI flags and JSON funnel here).
    pub fn apply(&mut self, key: &str, value: &str) -> Result<(), String> {
        fn p<T: std::str::FromStr>(k: &str, v: &str) -> Result<T, String> {
            v.parse()
                .map_err(|_| format!("bad value '{v}' for config key '{k}'"))
        }
        match key {
            "seed" => self.seed = p(key, value)?,
            "sentences" => self.sentences = p(key, value)?,
            "vocab" => self.vocab = p(key, value)?,
            "clusters" => self.clusters = p(key, value)?,
            "truth_dim" => self.truth_dim = p(key, value)?,
            "zipf_exponent" => self.zipf_exponent = p(key, value)?,
            "avg_sentence_len" => self.avg_sentence_len = p(key, value)?,
            "dim" => self.dim = p(key, value)?,
            "window" => self.window = p(key, value)?,
            "negatives" => self.negatives = p(key, value)?,
            "subsample_t" => self.subsample_t = p(key, value)?,
            "lr0" => self.lr0 = p(key, value)?,
            "lr_min" => self.lr_min = p(key, value)?,
            "epochs" => self.epochs = p(key, value)?,
            "min_count_base" => self.min_count_base = p(key, value)?,
            "strategy" => {
                self.strategy = DivideStrategy::parse(value)
                    .ok_or_else(|| format!("unknown strategy '{value}'"))?
            }
            "rate_percent" => {
                let r: f64 = p(key, value)?;
                validate_rate_percent(r)?;
                self.rate_percent = r;
            }
            "merge" => {
                self.merge = MergeMethod::parse(value)
                    .ok_or_else(|| format!("unknown merge method '{value}'"))?
            }
            "alir_rounds" => self.alir_rounds = p(key, value)?,
            "alir_tol" => self.alir_tol = p(key, value)?,
            "mappers" => self.mappers = p(key, value)?,
            "queue_capacity" => self.queue_capacity = p(key, value)?,
            "backend" => {
                self.backend = BackendKind::parse(value)
                    .ok_or_else(|| format!("unknown backend '{value}' (auto | native | xla)"))?
            }
            "artifact_dir" => self.artifact_dir = value.to_string(),
            "trainer_batch" => self.trainer_batch = p(key, value)?,
            "trainer_steps" => self.trainer_steps = p(key, value)?,
            _ => return Err(format!("unknown config key '{key}'")),
        }
        Ok(())
    }

    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let j = Json::parse(&text).map_err(|e| e.to_string())?;
        Self::from_json(&j)
    }
}

fn value_to_string(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_through_json() {
        let cfg = ExperimentConfig::default();
        let j = cfg.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.strategy, cfg.strategy);
        assert_eq!(back.merge, cfg.merge);
        assert_eq!(back.rate_percent, cfg.rate_percent);
        assert_eq!(back.lr0, cfg.lr0);
        assert_eq!(back.backend, cfg.backend);
    }

    #[test]
    fn backend_kind_parses_and_roundtrips() {
        for b in [BackendKind::Auto, BackendKind::Native, BackendKind::Xla] {
            assert_eq!(BackendKind::parse(b.name()), Some(b));
        }
        assert_eq!(BackendKind::parse("cpu"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("pjrt"), Some(BackendKind::Xla));
        assert!(BackendKind::parse("gpu").is_none());
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.backend, BackendKind::Auto);
        cfg.apply("backend", "native").unwrap();
        assert_eq!(cfg.backend, BackendKind::Native);
        assert!(cfg.apply("backend", "nonsense").is_err());
    }

    #[test]
    fn num_submodels_from_rate() {
        let mut cfg = ExperimentConfig::default();
        cfg.rate_percent = 10.0;
        assert_eq!(cfg.num_submodels(), 10);
        cfg.rate_percent = 1.0;
        assert_eq!(cfg.num_submodels(), 100);
        cfg.rate_percent = 33.0;
        assert_eq!(cfg.num_submodels(), 3);
        cfg.rate_percent = 100.0;
        assert_eq!(cfg.num_submodels(), 1);
    }

    #[test]
    fn submodel_min_count_scales_with_models() {
        let mut cfg = ExperimentConfig::default();
        cfg.min_count_base = 100.0;
        cfg.rate_percent = 10.0;
        assert_eq!(cfg.submodel_min_count(), 10);
        cfg.rate_percent = 50.0;
        assert_eq!(cfg.submodel_min_count(), 50);
    }

    #[test]
    fn rate_percent_is_validated_at_parse() {
        let mut cfg = ExperimentConfig::default();
        // lower boundary is exclusive …
        assert!(cfg.apply("rate_percent", "0").is_err());
        assert!(cfg.apply("rate_percent", "0.0").is_err());
        // … the upper one inclusive
        cfg.apply("rate_percent", "100").unwrap();
        assert_eq!(cfg.rate_percent, 100.0);
        assert!(cfg.apply("rate_percent", "100.0001").is_err());
        assert!(cfg.apply("rate_percent", "-3").is_err());
        assert!(cfg.apply("rate_percent", "NaN").is_err());
        assert!(cfg.apply("rate_percent", "inf").is_err());
        cfg.apply("rate_percent", "12.5").unwrap();
        assert_eq!(cfg.rate_percent, 12.5);
        // a rejected value must not clobber the previous one
        assert!(cfg.apply("rate_percent", "0").is_err());
        assert_eq!(cfg.rate_percent, 12.5);
        // the JSON path funnels through the same validation
        let j = Json::parse(r#"{"rate_percent": 0}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn apply_overrides() {
        let mut cfg = ExperimentConfig::default();
        cfg.apply("strategy", "equal").unwrap();
        assert_eq!(cfg.strategy, DivideStrategy::EqualPartitioning);
        cfg.apply("merge", "concat").unwrap();
        assert_eq!(cfg.merge, MergeMethod::Concat);
        cfg.apply("epochs", "7").unwrap();
        assert_eq!(cfg.epochs, 7);
        assert!(cfg.apply("nonsense", "1").is_err());
        assert!(cfg.apply("epochs", "x").is_err());
    }

    #[test]
    fn strategy_and_merge_names_roundtrip() {
        for s in [
            DivideStrategy::EqualPartitioning,
            DivideStrategy::RandomSampling,
            DivideStrategy::Shuffle,
        ] {
            assert_eq!(DivideStrategy::parse(s.name()), Some(s));
        }
        for m in [
            MergeMethod::Concat,
            MergeMethod::Pca,
            MergeMethod::AlirRand,
            MergeMethod::AlirPca,
            MergeMethod::Single,
        ] {
            assert_eq!(MergeMethod::parse(m.name()), Some(m));
        }
    }
}
