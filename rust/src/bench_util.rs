//! Hand-rolled bench harness (criterion is unavailable offline).
//!
//! Three modes cover the repo's needs:
//! * [`time_it`] — statistical micro/meso timing (warmup + N iterations,
//!   min/mean/p50/p95) for the perf benches;
//! * [`Table`] — paper-style result tables (one row per configuration)
//!   that print to stdout AND persist as JSON under `bench_results/` so
//!   EXPERIMENTS.md can quote them;
//! * [`append_bench_trajectory`] — longitudinal tracking: one JSON array
//!   per bench at the **repo root** (`BENCH_<name>.json`) that every run
//!   appends a row to, so regressions across PRs show up as a time
//!   series instead of a silently replaced snapshot. CI smoke-checks
//!   that the files exist and parse.

use crate::util::json::{arr, inum, num, obj, s, Json};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct TimingStats {
    pub iters: usize,
    pub min_secs: f64,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p95_secs: f64,
}

impl TimingStats {
    pub fn summary(&self) -> String {
        format!(
            "min {:.3}ms  mean {:.3}ms  p50 {:.3}ms  p95 {:.3}ms  ({} iters)",
            self.min_secs * 1e3,
            self.mean_secs * 1e3,
            self.p50_secs * 1e3,
            self.p95_secs * 1e3,
            self.iters
        )
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("iters", inum(self.iters)),
            ("min_secs", num(self.min_secs)),
            ("mean_secs", num(self.mean_secs)),
            ("p50_secs", num(self.p50_secs)),
            ("p95_secs", num(self.p95_secs)),
        ])
    }
}

/// Time a closure: `warmup` unmeasured runs, then `iters` measured runs.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> TimingStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    TimingStats {
        iters: n,
        min_secs: samples[0],
        mean_secs: samples.iter().sum::<f64>() / n as f64,
        p50_secs: samples[n / 2],
        p95_secs: samples[(n * 95 / 100).min(n - 1)],
    }
}

/// A paper-style results table that also persists as JSON.
pub struct Table {
    name: String,
    title: String,
    header: Vec<String>,
    rows: Vec<(String, Vec<String>)>,
    json_rows: Vec<Json>,
}

impl Table {
    pub fn new(name: &str, title: &str, header: &[&str]) -> Self {
        Self {
            name: name.to_string(),
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            json_rows: Vec::new(),
        }
    }

    /// Add a display row plus its machine-readable form.
    pub fn row(&mut self, label: &str, cells: Vec<String>, json: Json) {
        self.rows.push((label.to_string(), cells));
        self.json_rows.push(json);
    }

    /// Print to stdout and write `bench_results/<name>.json`.
    pub fn finish(self) {
        println!("\n=== {} ===", self.title);
        let mut head = format!("{:<30}", "");
        for h in &self.header {
            head.push_str(&format!(" {h:<13}"));
        }
        println!("{head}");
        for (label, cells) in &self.rows {
            let mut line = format!("{label:<30}");
            for c in cells {
                line.push_str(&format!(" {c:<13}"));
            }
            println!("{line}");
        }
        let out = obj(vec![
            ("bench", s(&self.name)),
            ("title", s(&self.title)),
            ("header", arr(self.header.iter().map(|h| s(h)).collect())),
            ("rows", arr(self.json_rows)),
        ]);
        let dir = std::path::Path::new("bench_results");
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(format!("{}.json", self.name));
            if let Err(e) = std::fs::write(&path, out.to_string_pretty()) {
                eprintln!("warn: could not persist {}: {e}", path.display());
            } else {
                println!("[saved {}]", path.display());
            }
        }
    }
}

/// Append one run's headline numbers to the bench's trajectory file.
///
/// Trajectory files live at the **repo root** (one directory above the
/// crate, next to EXPERIMENTS.md) as `BENCH_<name>.json`, each holding a
/// JSON array with one object per recorded run, oldest first. Unlike the
/// `bench_results/` snapshots — which each run overwrites — the
/// trajectory only grows, so a perf regression between PRs is visible as
/// a bend in the series rather than a silently replaced number. Each
/// appended row is stamped with a `unix_secs` timestamp.
///
/// Robustness over strictness: a missing, empty or unparseable existing
/// file starts a fresh array (with a warning) instead of failing the
/// bench, and the write is atomic (temp + rename) so a crashed bench
/// never leaves a torn file. `DW2V_BENCH_DIR` overrides the target
/// directory — CI and the unit test point it at a scratch dir.
pub fn append_bench_trajectory(name: &str, row: Json) {
    let dir = match crate::util::env::bench_dir() {
        Some(d) => std::path::PathBuf::from(d),
        None => std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(".."),
    };
    let path = dir.join(format!("BENCH_{name}.json"));

    let mut rows: Vec<Json> = match std::fs::read_to_string(&path) {
        Ok(text) => match Json::parse(&text) {
            Ok(Json::Arr(items)) => items,
            Ok(_) => {
                eprintln!(
                    "warn: {} is not a JSON array — starting a fresh trajectory",
                    path.display()
                );
                Vec::new()
            }
            Err(e) => {
                eprintln!(
                    "warn: {} did not parse ({e:?}) — starting a fresh trajectory",
                    path.display()
                );
                Vec::new()
            }
        },
        Err(_) => Vec::new(),
    };

    let unix_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let rss = peak_rss_mb();
    let stamped = match row {
        Json::Obj(mut map) => {
            map.insert("unix_secs".to_string(), num(unix_secs));
            if let Some(mb) = rss {
                map.insert("peak_rss_mb".to_string(), num(mb));
            }
            Json::Obj(map)
        }
        other => obj(vec![("unix_secs", num(unix_secs)), ("row", other)]),
    };
    rows.push(stamped);

    let tmp = path.with_extension("json.tmp");
    let body = arr(rows).to_string_pretty();
    let write = std::fs::write(&tmp, body).and_then(|_| std::fs::rename(&tmp, &path));
    match write {
        Ok(()) => println!("[trajectory {}]", path.display()),
        Err(e) => eprintln!("warn: could not persist {}: {e}", path.display()),
    }
}

/// Peak resident-set size of this process in MB, from `/proc` (`VmHWM`,
/// the high-water mark — monotone over the process lifetime, so a bench
/// that runs after a bigger one in the same process reads the bigger
/// one's peak). `None` off Linux or when `/proc` is unreadable; callers
/// (and the trajectory stamp) just omit the column then.
pub fn peak_rss_mb() -> Option<f64> {
    #[cfg(target_os = "linux")]
    {
        let status = std::fs::read_to_string("/proc/self/status").ok()?;
        let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
        let kb: f64 = line
            .split_whitespace()
            .nth(1)?
            .parse()
            .ok()?;
        Some(kb / 1024.0)
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// Quick scale knob for benches: DW2V_BENCH_SCALE=small|full (default small
/// keeps every bench under a couple of minutes on CPU).
pub fn bench_scale() -> f64 {
    if crate::util::env::bench_full_scale() {
        1.0
    } else {
        0.25
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures() {
        let stats = time_it(1, 5, || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(stats.min_secs >= 0.0015);
        assert!(stats.mean_secs >= stats.min_secs);
        assert!(stats.p95_secs >= stats.p50_secs);
        assert_eq!(stats.iters, 5);
    }

    #[test]
    fn trajectory_appends_and_survives_garbage() {
        let dir = std::env::temp_dir().join(format!("dw2v_traj_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("DW2V_BENCH_DIR", &dir);
        let path = dir.join("BENCH_unit_traj.json");

        append_bench_trajectory("unit_traj", obj(vec![("mbps", num(12.5))]));
        append_bench_trajectory("unit_traj", obj(vec![("mbps", num(13.0))]));
        let rows = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let rows = rows.as_arr().expect("trajectory is an array").to_vec();
        assert_eq!(rows.len(), 2, "two runs -> two rows");
        assert_eq!(rows[0].get("mbps").as_f64(), Some(12.5));
        assert_eq!(rows[1].get("mbps").as_f64(), Some(13.0));
        assert!(
            rows[1].get("unix_secs").as_f64().is_some(),
            "rows are timestamped"
        );
        #[cfg(target_os = "linux")]
        assert!(
            rows[1].get("peak_rss_mb").as_f64().unwrap_or(0.0) > 0.0,
            "linux rows carry the peak-RSS column"
        );

        // a torn/garbage file starts a fresh series instead of failing
        std::fs::write(&path, "{not json").unwrap();
        append_bench_trajectory("unit_traj", obj(vec![("mbps", num(14.0))]));
        let rows = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(rows.as_arr().unwrap().len(), 1);

        std::env::remove_var("DW2V_BENCH_DIR");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn table_does_not_panic_and_persists() {
        let mut t = Table::new("unit_test_table", "Unit", &["a", "b"]);
        t.row(
            "row1",
            vec!["1".into(), "2".into()],
            obj(vec![("a", num(1.0))]),
        );
        t.finish();
        let path = std::path::Path::new("bench_results/unit_test_table.json");
        assert!(path.exists());
        let _ = std::fs::remove_file(path);
    }
}
