//! # xtask — first-party static analysis for the dw2v workspace
//!
//! `cargo xtask lint` (aliased in `.cargo/config.toml`) lexes every
//! `rust/src/**/*.rs` file with the repo's delimiter-scan technique and
//! enforces the architecture invariants the paper's zero-synchronization
//! design depends on. The rules are conventions that previous PRs
//! introduced and that review alone had been guarding; async-training
//! bugs surface as silent quality loss rather than crashes, so the
//! invariants are machine-checked before the remote-membership and SIMD
//! work churns these layers.
//!
//! ## Rule catalog
//!
//! | id | invariant | introduced |
//! |----|-----------|------------|
//! | `fs-outside-seam` | R1: the coordinator layer never touches the filesystem directly; every shard/artifact/beacon/checkpoint exchange goes through `transport::{ShardStore, ArtifactStore, ControlPlane}`. Keeps the FS and TCP transports interchangeable (bitwise-equal merges). | PR 9 |
//! | `final-path-create` | R2: final artifact names (`*.dwsm`, `*.ckpt`, `shards.json`, `beacon_*.json`, `BENCH_*.json`) are never written in place — publish to a tmp name, then rename. Readers (feed manifest, beacon poller, artifact collector) rely on never observing a torn file. | PR 5/6/7 |
//! | `json-int-precision` | R3: integers never enter JSON as a bare `x as f64` — `util::json::inum` (checked number, panics past 2^53), `util::json::u64s` (decimal string, for counters that can exceed 2^53) or `util::json::fnum` (exact f32 widening) make the precision contract explicit. | PR 7/8 |
//! | `env-var-outside-env` | R4: `env::var` appears only in `util/env.rs`; every `DW2V_*` knob is read, validated and documented in one table. | PR 9 |
//! | `nondeterministic-call` | R5: `SystemTime::now` / `rand::` never appear in `coordinator/divider.rs`, `sgns/trainer.rs` or `runtime/native.rs` — the checkpoint-resume, overlap and FS-vs-TCP equivalence tests all assert *bitwise* identical models, which only holds while routing and training are pure functions of the config. | PR 5/6/7 |
//! | `unhandled-message` | R6: every `pub const MSG_*` in `transport/frame.rs` is matched somewhere in `transport/server.rs` — adding a frame type without a dispatch arm is a compile-time-invisible protocol hole. | PR 9 |
//! | `relaxed-ordering` | R7: `Ordering::Relaxed` is sanctioned only in `obs/metrics.rs` and `sgns/hogwild.rs` (the documented lock-free hot paths, covered by the loom/TSan jobs); anywhere else it needs a `lint-allow` justification. | PR 1/8 |
//! | `bad-lint-allow` | meta: a `lint-allow` naming an unknown rule, or carrying no reason, is itself an error — suppressions stay auditable. | PR 10 |
//!
//! ## Suppression
//!
//! ```text
//! counter.fetch_add(1, Ordering::Relaxed); // lint-allow: relaxed-ordering monotonic telemetry
//! ```
//!
//! A `lint-allow` comment silences a finding of the named rule on the
//! same line or the line directly below the comment. `#[cfg(test)] mod`
//! blocks are exempt from all rules.
//!
//! ## Scope and limits
//!
//! The linter sees `rust/src/**/*.rs` only (benches, tests/ and this
//! crate are out of scope) and matches tokens in a comment- and
//! string-blanked view of the source, so it cannot be fooled by literals
//! or doc text — but it is a lexer, not a type checker: it enforces
//! *conventions at the call-site spelling level*, which is exactly how
//! the conventions are written. The dynamic side (loom models under
//! `--cfg loom`, ThreadSanitizer and Miri CI jobs) covers what a lexer
//! cannot: the actual memory-ordering protocols of the allowlisted
//! modules.

pub mod rules;
pub mod scan;

pub use rules::{lint_files, lint_files_full, Finding, RULES};

use std::path::{Path, PathBuf};

/// Collect every `.rs` file under `root/rust/src`, sorted, as
/// `(repo-relative path, contents)` pairs.
pub fn collect_sources(root: &Path) -> std::io::Result<Vec<(String, String)>> {
    let src = root.join("rust").join("src");
    let mut paths = Vec::new();
    walk(&src, &mut paths)?;
    paths.sort();
    let mut files = Vec::new();
    for p in paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push((rel, std::fs::read_to_string(&p)?));
    }
    Ok(files)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint the tree rooted at `root` (the directory containing `rust/src`).
/// Returns `(unsuppressed findings, suppressed count, files seen)`.
pub fn lint_tree(root: &Path) -> std::io::Result<(Vec<Finding>, usize, usize)> {
    let files = collect_sources(root)?;
    let n = files.len();
    let (findings, suppressed) = lint_files_full(&files);
    Ok((findings, suppressed, n))
}

/// Walk upward from `start` to the first directory containing `rust/src`.
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut dir = start.to_path_buf();
    loop {
        if dir.join("rust").join("src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}
