//! The rule catalog. Each rule is a small substring/paren-scan check over
//! the blanked code view from [`crate::scan`]; the catalog text below is
//! the normative description (also printed by `cargo xtask lint --rules`).
//!
//! Suppression: any finding can be silenced with a trailing (or
//! directly-above) `// lint-allow: <rule-id> <reason>` comment. The
//! reason is mandatory and an unknown rule id is itself an error
//! (`bad-lint-allow`), so suppressions stay auditable. `#[cfg(test)]
//! mod` blocks are exempt from every rule — test fixtures may take
//! shortcuts without ceremony.

use crate::scan::{balanced_arg, find_bounded, FileView};

/// One lint finding. `path` is repo-relative, `line` 1-based.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{} {} — {}", self.path, self.line, self.rule, self.msg)
    }
}

/// `(id, summary)` for every rule, in catalog order.
pub const RULES: &[(&str, &str)] = &[
    (
        "fs-outside-seam",
        "R1: no direct filesystem calls in coordinator/ — all shard/artifact/beacon/checkpoint \
         I/O goes through the transport seams (ShardStore/ArtifactStore/ControlPlane, PR 9)",
    ),
    (
        "final-path-create",
        "R2: never File::create/fs::write/fs::copy a final artifact path (*.dwsm, *.ckpt, \
         shards.json, beacon_*.json, BENCH_*.json) — publish tmp, then rename (PR 5/6/7)",
    ),
    (
        "json-int-precision",
        "R3: no bare `num(x as f64)` / `Json::Num(x as f64)` — integers entering JSON go \
         through util::json::{inum, u64s} (and f32 fields through fnum), which enforce the \
         2^53 precision ceiling (PR 7/8)",
    ),
    (
        "env-var-outside-env",
        "R4: `env::var` only inside util/env.rs — every DW2V_* knob is read, documented and \
         validated in one place (PR 9)",
    ),
    (
        "nondeterministic-call",
        "R5: no SystemTime::now / rand:: in the bitwise-deterministic paths \
         (coordinator/divider.rs, sgns/trainer.rs, runtime/native.rs) — resume/overlap \
         equivalence proofs depend on them being pure (PR 5/6/7)",
    ),
    (
        "unhandled-message",
        "R6: every `pub const MSG_*` frame type in transport/frame.rs must be dispatched in \
         transport/server.rs (PR 9)",
    ),
    (
        "relaxed-ordering",
        "R7: Ordering::Relaxed outside the allowlisted lock-free modules (obs/metrics.rs, \
         sgns/hogwild.rs) requires a lint-allow justification (PR 1/8)",
    ),
    (
        "bad-lint-allow",
        "meta: a lint-allow comment with an unknown rule id or no reason is itself a finding",
    ),
];

/// Modules whose lock-free protocols are documented at module level and
/// verified by the loom/TSan jobs — `Ordering::Relaxed` is sanctioned.
const RELAXED_ALLOWLIST: &[&str] = &["rust/src/obs/metrics.rs", "rust/src/sgns/hogwild.rs"];

/// Paths whose output must be bitwise-deterministic from the config.
const DETERMINISTIC_PATHS: &[&str] = &[
    "rust/src/coordinator/divider.rs",
    "rust/src/sgns/trainer.rs",
    "rust/src/runtime/native.rs",
];

/// Final (post-rename) artifact names — the tmp→rename publication set.
const FINAL_PATTERNS: &[&str] = &[".dwsm", ".ckpt", "shards.json", "beacon_", "BENCH_"];

const ENV_HOME: &str = "rust/src/util/env.rs";
const JSON_HOME: &str = "rust/src/util/json.rs";
const COORDINATOR_DIR: &str = "rust/src/coordinator/";
const FRAME_FILE: &str = "rust/src/transport/frame.rs";
const SERVER_FILE: &str = "rust/src/transport/server.rs";

fn known_rule(id: &str) -> bool {
    RULES.iter().any(|(r, _)| *r == id)
}

/// Lint a set of `(repo-relative path, contents)` sources. Returns only
/// the unsuppressed findings, sorted by path and line.
pub fn lint_files(files: &[(String, String)]) -> Vec<Finding> {
    lint_files_full(files).0
}

/// As [`lint_files`], but also returns the count of suppressed findings.
pub fn lint_files_full(files: &[(String, String)]) -> (Vec<Finding>, usize) {
    let views: Vec<FileView> = files
        .iter()
        .map(|(path, text)| FileView::new(path, text))
        .collect();
    let mut findings = Vec::new();
    for view in &views {
        check_env_var(view, &mut findings);
        check_coordinator_fs(view, &mut findings);
        check_final_path_create(view, &mut findings);
        check_json_int_cast(view, &mut findings);
        check_nondeterminism(view, &mut findings);
        check_relaxed_ordering(view, &mut findings);
    }
    check_frame_dispatch(&views, &mut findings);

    // apply suppressions, then validate the allow comments themselves
    let mut kept = Vec::new();
    let mut suppressed = 0usize;
    for f in findings {
        let view = views.iter().find(|v| v.path == f.path);
        let allowed = view.is_some_and(|v| {
            v.allows.iter().any(|a| {
                a.rule == f.rule
                    && !a.reason.is_empty()
                    && (a.line == f.line || a.line + 1 == f.line)
            })
        });
        if allowed {
            suppressed += 1;
        } else {
            kept.push(f);
        }
    }
    for view in &views {
        for a in &view.allows {
            if !known_rule(&a.rule) {
                kept.push(Finding {
                    rule: "bad-lint-allow",
                    path: view.path.clone(),
                    line: a.line,
                    msg: format!("unknown rule {:?} in lint-allow", a.rule),
                });
            } else if a.reason.is_empty() {
                kept.push(Finding {
                    rule: "bad-lint-allow",
                    path: view.path.clone(),
                    line: a.line,
                    msg: format!("lint-allow: {} needs a written reason", a.rule),
                });
            }
        }
    }
    kept.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    (kept, suppressed)
}

fn emit(out: &mut Vec<Finding>, rule: &'static str, view: &FileView, off: usize, msg: String) {
    out.push(Finding {
        rule,
        path: view.path.clone(),
        line: view.line_of(off),
        msg,
    });
}

/// R4 — `env::var` (and `env::var_os`) anywhere outside util/env.rs.
fn check_env_var(view: &FileView, out: &mut Vec<Finding>) {
    if view.path == ENV_HOME {
        return;
    }
    for off in find_bounded(&view.code, "env::var", true) {
        if view.in_test(off) {
            continue;
        }
        emit(
            out,
            "env-var-outside-env",
            view,
            off,
            "direct environment read; DW2V_* knobs go through util::env".to_string(),
        );
    }
}

/// R1 — direct filesystem access in coordinator/.
fn check_coordinator_fs(view: &FileView, out: &mut Vec<Finding>) {
    if !view.path.starts_with(COORDINATOR_DIR) {
        return;
    }
    for needle in ["std::fs::", "fs::", "File::", "OpenOptions::"] {
        for off in find_bounded(&view.code, needle, false) {
            if view.in_test(off) {
                continue;
            }
            emit(
                out,
                "fs-outside-seam",
                view,
                off,
                format!("direct filesystem call `{needle}` in the coordinator layer"),
            );
        }
    }
}

/// R2 — writing a final artifact path without tmp→rename. The argument
/// span is taken from the *raw* view so path fragments inside string
/// literals are visible.
fn check_final_path_create(view: &FileView, out: &mut Vec<Finding>) {
    for needle in ["File::create(", "fs::write(", "fs::copy("] {
        for off in find_bounded(&view.code, needle, true) {
            if view.in_test(off) {
                continue;
            }
            let open = off + needle.len() - 1;
            let arg = balanced_arg(&view.raw, open);
            let hits: Vec<&str> = FINAL_PATTERNS
                .iter()
                .filter(|p| arg.contains(*p))
                .copied()
                .collect();
            if !hits.is_empty() {
                emit(
                    out,
                    "final-path-create",
                    view,
                    off,
                    format!(
                        "writes final artifact path ({}) directly — publish to a tmp name \
                         and rename",
                        hits.join(", ")
                    ),
                );
            }
        }
    }
}

/// R3 — whole-argument integer→f64 casts entering a JSON number.
fn check_json_int_cast(view: &FileView, out: &mut Vec<Finding>) {
    if view.path == JSON_HOME {
        return; // the helpers' own implementation performs the checked cast
    }
    for needle in ["num(", "Num("] {
        for off in find_bounded(&view.code, needle, true) {
            if view.in_test(off) {
                continue;
            }
            let open = off + needle.len() - 1;
            let arg = balanced_arg(&view.code, open).trim();
            if arg.ends_with("as f64") {
                emit(
                    out,
                    "json-int-precision",
                    view,
                    off,
                    format!(
                        "`{needle}{arg})` — use util::json::inum / fnum / u64s so the \
                         2^53 ceiling is enforced"
                    ),
                );
            }
        }
    }
}

/// R5 — nondeterminism in the bitwise-deterministic paths.
fn check_nondeterminism(view: &FileView, out: &mut Vec<Finding>) {
    if !DETERMINISTIC_PATHS.contains(&view.path.as_str()) {
        return;
    }
    for needle in ["SystemTime::now", "rand::"] {
        for off in find_bounded(&view.code, needle, false) {
            if view.in_test(off) {
                continue;
            }
            emit(
                out,
                "nondeterministic-call",
                view,
                off,
                format!("`{needle}` in a bitwise-deterministic path"),
            );
        }
    }
}

/// R7 — Relaxed ordering outside the sanctioned lock-free modules.
fn check_relaxed_ordering(view: &FileView, out: &mut Vec<Finding>) {
    if RELAXED_ALLOWLIST.contains(&view.path.as_str()) {
        return;
    }
    for (off, _) in view.code.match_indices("Ordering::Relaxed") {
        if view.in_test(off) {
            continue;
        }
        emit(
            out,
            "relaxed-ordering",
            view,
            off,
            "Relaxed ordering outside obs/metrics.rs and sgns/hogwild.rs — justify with a \
             lint-allow or use Acquire/Release"
                .to_string(),
        );
    }
}

/// R6 — every frame message constant must appear in the server dispatch.
fn check_frame_dispatch(views: &[FileView], out: &mut Vec<Finding>) {
    let Some(frame) = views.iter().find(|v| v.path == FRAME_FILE) else {
        return;
    };
    let Some(server) = views.iter().find(|v| v.path == SERVER_FILE) else {
        return;
    };
    for (off, _) in frame.code.match_indices("pub const MSG_") {
        let rest = &frame.code[off + "pub const ".len()..];
        let name: String = rest
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if !rest[name.len()..].trim_start().starts_with(':') {
            continue;
        }
        let handled = find_bounded(&server.code, &name, true).into_iter().any(|p| {
            let after = server.code.as_bytes().get(p + name.len());
            !matches!(after, Some(b) if b.is_ascii_alphanumeric() || *b == b'_')
        });
        if !handled {
            emit(
                out,
                "unhandled-message",
                frame,
                off,
                format!("{name} is not handled in transport/server.rs dispatch"),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_one(path: &str, src: &str) -> Vec<Finding> {
        lint_files(&[(path.to_string(), src.to_string())])
    }

    #[test]
    fn clean_file_has_no_findings() {
        let src = "pub fn f() -> u64 {\n    42\n}\n";
        assert!(lint_one("rust/src/x.rs", src).is_empty());
    }

    #[test]
    fn suppression_must_name_a_known_rule_and_a_reason() {
        let src = "use std::sync::atomic::Ordering;\n\
                   fn f(a: &std::sync::atomic::AtomicU64) -> u64 {\n\
                   a.load(Ordering::Relaxed) // lint-allow: relaxed-ordering telemetry only\n\
                   }\n";
        assert!(lint_one("rust/src/x.rs", src).is_empty());

        let bad_rule = src.replace("relaxed-ordering telemetry only", "no-such-rule yes");
        let f = lint_one("rust/src/x.rs", &bad_rule);
        assert_eq!(f.len(), 2, "{f:?}"); // the finding survives + bad-lint-allow
        assert!(f.iter().any(|x| x.rule == "bad-lint-allow"));
        assert!(f.iter().any(|x| x.rule == "relaxed-ordering"));

        let no_reason = src.replace(" telemetry only", "");
        let f = lint_one("rust/src/x.rs", &no_reason);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().any(|x| x.rule == "bad-lint-allow"
            && x.msg.contains("needs a written reason")));
    }
}
