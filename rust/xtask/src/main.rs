//! `cargo xtask <command>` — workspace automation. The only command today
//! is `lint`; see the crate docs ([`xtask`]) for the rule catalog.

use std::path::PathBuf;
use std::process::ExitCode;

fn usage() -> ExitCode {
    eprintln!("usage: cargo xtask lint [--root DIR] [--rules]");
    eprintln!();
    eprintln!("  lint          check rust/src/**/*.rs against the invariant catalog;");
    eprintln!("                exit 1 when any unsuppressed finding remains");
    eprintln!("  --root DIR    lint the tree rooted at DIR (default: walk up from cwd");
    eprintln!("                to the first directory containing rust/src)");
    eprintln!("  --rules       print the rule catalog and exit");
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root: Option<PathBuf> = None;
    let mut print_rules = false;
    let mut cmd: Option<String> = None;
    let mut it = args.into_iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(dir) => root = Some(PathBuf::from(dir)),
                None => return usage(),
            },
            "--rules" => print_rules = true,
            "lint" if cmd.is_none() => cmd = Some(a),
            _ => return usage(),
        }
    }
    if cmd.as_deref() != Some("lint") {
        return usage();
    }
    if print_rules {
        for (id, doc) in xtask::RULES {
            println!("{id}\n    {doc}\n");
        }
        return ExitCode::SUCCESS;
    }
    let root = match root {
        Some(r) => r,
        None => {
            let cwd = std::env::current_dir().expect("cwd");
            match xtask::find_root(&cwd) {
                Some(r) => r,
                None => {
                    eprintln!("lint: no rust/src found above {}", cwd.display());
                    return ExitCode::from(2);
                }
            }
        }
    };
    match xtask::lint_tree(&root) {
        Ok((findings, suppressed, files)) => {
            for f in &findings {
                println!("{f}");
            }
            if findings.is_empty() {
                println!(
                    "lint: clean — {files} files, {suppressed} suppressed finding(s)"
                );
                ExitCode::SUCCESS
            } else {
                println!(
                    "lint: {} finding(s) in {files} files ({suppressed} suppressed)",
                    findings.len()
                );
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("lint: cannot read tree at {}: {e}", root.display());
            ExitCode::from(2)
        }
    }
}
