//! Delimiter-scan lexer: the same technique the repo's JSON parser and
//! shard readers use, applied to Rust source.
//!
//! [`FileView::new`] walks a file once and produces:
//!
//! * `code` — the source with every comment and every string/char
//!   literal *content* replaced by spaces (delimiters kept), byte
//!   positions preserved. Rules match against this view so `"fs::write"`
//!   inside a log message can never trip a rule.
//! * `raw` — the untouched source, used when a rule needs to look
//!   *inside* string literals (e.g. the final-artifact path patterns of
//!   rule `final-path-create`).
//! * `allows` — every `// lint-allow: <rule> <reason>` comment, with its
//!   line number. A finding is suppressed by an allow for the same rule
//!   on the same line (trailing comment) or the line directly above.
//! * `test_spans` — byte ranges of `#[cfg(test)] mod … { … }` blocks.
//!   Findings inside them are dropped: test code may take shortcuts
//!   (direct `fs::` fixtures, `Relaxed` counters) without ceremony.
//!
//! The lexer understands line comments, nested block comments, string
//! literals with escapes, raw strings (`r#"…"#`, any hash depth, with
//! `b` prefixes), char literals (including escaped ones) and leaves
//! lifetimes alone. That is the entire Rust surface this repo uses.

/// One `// lint-allow: <rule> <reason>` comment.
#[derive(Debug, Clone)]
pub struct Allow {
    pub line: usize,
    pub rule: String,
    pub reason: String,
}

/// A lexed source file, ready for the rules in [`crate::rules`].
pub struct FileView {
    /// path relative to the repo root, e.g. `rust/src/obs/metrics.rs`
    pub path: String,
    /// comments and literal contents blanked; byte-identical layout
    pub code: String,
    /// the file exactly as read
    pub raw: String,
    pub allows: Vec<Allow>,
    /// byte ranges of `#[cfg(test)] mod` blocks in `code`/`raw`
    pub test_spans: Vec<(usize, usize)>,
}

fn utf8_len(lead: u8) -> usize {
    if lead < 0x80 {
        1
    } else if lead >> 5 == 0b110 {
        2
    } else if lead >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

impl FileView {
    pub fn new(path: &str, raw: &str) -> FileView {
        let bytes = raw.as_bytes();
        let n = bytes.len();
        let mut code = Vec::with_capacity(n);
        let mut comments: Vec<(usize, String)> = Vec::new();
        let mut line = 1usize;
        let mut i = 0usize;
        while i < n {
            let c = bytes[i];
            let nxt = if i + 1 < n { bytes[i + 1] } else { 0 };
            // line comment — capture its text for lint-allow parsing
            if c == b'/' && nxt == b'/' {
                let start = i + 2;
                let mut j = start;
                while j < n && bytes[j] != b'\n' {
                    j += 1;
                }
                let text = String::from_utf8_lossy(&bytes[start..j]).into_owned();
                comments.push((line, text));
                code.resize(code.len() + (j - i), b' ');
                i = j;
                continue;
            }
            // block comment (nested)
            if c == b'/' && nxt == b'*' {
                let mut depth = 0usize;
                while i < n {
                    if bytes[i] == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
                        depth += 1;
                        code.extend_from_slice(b"  ");
                        i += 2;
                    } else if bytes[i] == b'*' && i + 1 < n && bytes[i + 1] == b'/' {
                        depth -= 1;
                        code.extend_from_slice(b"  ");
                        i += 2;
                        if depth == 0 {
                            break;
                        }
                    } else {
                        if bytes[i] == b'\n' {
                            line += 1;
                            code.push(b'\n');
                        } else {
                            code.push(b' ');
                        }
                        i += 1;
                    }
                }
                continue;
            }
            // raw string (with optional b prefix): r"…", r#"…"#, br"…"
            if c == b'r' || (c == b'b' && nxt == b'r') {
                let mut j = i + if c == b'r' { 1 } else { 2 };
                let mut hashes = 0usize;
                while j < n && bytes[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && bytes[j] == b'"' {
                    code.extend_from_slice(&bytes[i..=j]);
                    i = j + 1;
                    loop {
                        if i >= n {
                            break;
                        }
                        if bytes[i] == b'"'
                            && bytes[i + 1..].len() >= hashes
                            && bytes[i + 1..i + 1 + hashes].iter().all(|&b| b == b'#')
                        {
                            code.push(b'"');
                            code.resize(code.len() + hashes, b'#');
                            i += 1 + hashes;
                            break;
                        }
                        if bytes[i] == b'\n' {
                            line += 1;
                            code.push(b'\n');
                        } else {
                            code.push(b' ');
                        }
                        i += 1;
                    }
                    continue;
                }
                // plain identifier starting with r/br — fall through
            }
            // string literal
            if c == b'"' {
                code.push(b'"');
                i += 1;
                while i < n {
                    if bytes[i] == b'\\' && i + 1 < n {
                        code.extend_from_slice(b"  ");
                        i += 2;
                    } else if bytes[i] == b'"' {
                        code.push(b'"');
                        i += 1;
                        break;
                    } else {
                        if bytes[i] == b'\n' {
                            line += 1;
                            code.push(b'\n');
                        } else {
                            code.push(b' ');
                        }
                        i += 1;
                    }
                }
                continue;
            }
            // char literal vs lifetime
            if c == b'\'' && i + 1 < n {
                if bytes[i + 1] == b'\\' {
                    // escaped char literal: '\n', '\'', '\u{8}' …
                    let mut j = i + 3; // skip quote, backslash, escaped byte
                    while j < n && bytes[j] != b'\'' {
                        j += 1;
                    }
                    code.push(b'\'');
                    code.resize(code.len() + (j - i - 1), b' ');
                    code.push(b'\'');
                    i = j + 1;
                    continue;
                }
                let ch = utf8_len(bytes[i + 1]);
                if bytes[i + 1] != b'\'' && i + 1 + ch < n && bytes[i + 1 + ch] == b'\'' {
                    // plain char literal: 'x' (possibly multibyte)
                    code.push(b'\'');
                    code.resize(code.len() + ch, b' ');
                    code.push(b'\'');
                    i += 2 + ch;
                    continue;
                }
                // lifetime — keep the quote, stay in code state
            }
            if c == b'\n' {
                line += 1;
            }
            code.push(c);
            i += 1;
        }
        let code = String::from_utf8(code).expect("blanking preserves utf8");
        debug_assert_eq!(code.len(), raw.len());
        let test_spans = find_test_spans(&code);
        let allows = parse_allows(&comments);
        FileView {
            path: path.to_string(),
            code,
            raw: raw.to_string(),
            allows,
            test_spans,
        }
    }

    /// Is this byte offset inside a `#[cfg(test)] mod` block?
    pub fn in_test(&self, offset: usize) -> bool {
        self.test_spans.iter().any(|&(a, b)| a <= offset && offset < b)
    }

    /// 1-based line number of a byte offset.
    pub fn line_of(&self, offset: usize) -> usize {
        self.code[..offset].bytes().filter(|&b| b == b'\n').count() + 1
    }
}

/// Byte ranges of `#[cfg(test)] mod name { … }` blocks in the code view.
fn find_test_spans(code: &str) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let needle = "#[cfg(test)]";
    for (pos, _) in code.match_indices(needle) {
        let mut j = pos + needle.len();
        let bytes = code.as_bytes();
        while j < bytes.len() && (bytes[j] as char).is_whitespace() {
            j += 1;
        }
        if code[j..].starts_with("pub ") {
            j += 4;
            while j < bytes.len() && (bytes[j] as char).is_whitespace() {
                j += 1;
            }
        }
        let after_mod = &code[j..];
        if !after_mod.starts_with("mod")
            || !after_mod[3..].starts_with(|c: char| c.is_whitespace())
        {
            continue;
        }
        // scan to the opening brace (a `mod name;` file reference has
        // none — stop at the `;`), then brace-match to the block end
        let open = match after_mod.find(['{', ';']) {
            Some(k) if after_mod.as_bytes()[k] == b'{' => j + k,
            _ => continue,
        };
        let mut depth = 0usize;
        let mut end = code.len();
        for (k, b) in code.bytes().enumerate().skip(open) {
            match b {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        end = k + 1;
                        break;
                    }
                }
                _ => {}
            }
        }
        spans.push((pos, end));
    }
    spans
}

/// Extract `lint-allow: <rule> <reason…>` from the captured line comments.
/// Malformed allows (missing rule or empty reason) are kept with an empty
/// field so the rules layer can report them as `bad-lint-allow`.
fn parse_allows(comments: &[(usize, String)]) -> Vec<Allow> {
    let mut allows = Vec::new();
    for (line, text) in comments {
        let Some(pos) = text.find("lint-allow:") else {
            continue;
        };
        let rest = text[pos + "lint-allow:".len()..].trim();
        let mut words = rest.splitn(2, char::is_whitespace);
        let rule = words.next().unwrap_or("").to_string();
        let reason = words.next().unwrap_or("").trim().to_string();
        allows.push(Allow {
            line: *line,
            rule,
            reason,
        });
    }
    allows
}

/// Find every occurrence of `needle` in `hay` whose preceding byte is not
/// an identifier byte (and, when `allow_colon` is false, not a `:` — used
/// to avoid re-matching `fs::` inside an already-matched `std::fs::`).
pub fn find_bounded(hay: &str, needle: &str, allow_colon: bool) -> Vec<usize> {
    hay.match_indices(needle)
        .filter(|(pos, _)| {
            if *pos == 0 {
                return true;
            }
            let prev = hay.as_bytes()[pos - 1];
            let ident = prev.is_ascii_alphanumeric() || prev == b'_';
            !ident && (allow_colon || prev != b':')
        })
        .map(|(pos, _)| pos)
        .collect()
}

/// The contents of the balanced-paren span starting at `open` (which must
/// point at `(`). Returns the text between the parens.
pub fn balanced_arg(text: &str, open: usize) -> &str {
    let bytes = text.as_bytes();
    let mut depth = 0usize;
    for (k, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return &text[open + 1..k];
                }
            }
            _ => {}
        }
    }
    &text[open + 1..]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blanking_hides_strings_and_comments_but_keeps_layout() {
        let src = "let x = \"fs::write\"; // fs::write\nfs::write(p);\n";
        let v = FileView::new("rust/src/t.rs", src);
        assert_eq!(v.code.len(), src.len());
        assert_eq!(v.code.matches("fs::write").count(), 1);
        assert_eq!(v.line_of(v.code.find("fs::write").unwrap()), 2);
    }

    #[test]
    fn raw_strings_and_char_literals_are_blanked() {
        let src = "let a = r#\"Ordering::Relaxed\"#; let b = '\\''; let c: &'static str = \"\";";
        let v = FileView::new("rust/src/t.rs", src);
        assert_eq!(v.code.len(), src.len());
        assert!(!v.code.contains("Ordering::Relaxed"));
        assert!(v.code.contains("&'static str"));
    }

    #[test]
    fn nested_block_comments_are_blanked() {
        let src = "/* outer /* env::var */ still */ env::var(\"X\")";
        let v = FileView::new("rust/src/t.rs", src);
        assert_eq!(v.code.matches("env::var").count(), 1);
    }

    #[test]
    fn cfg_test_mod_spans_are_found() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() { fs::write(p); }\n}\n";
        let v = FileView::new("rust/src/t.rs", src);
        assert_eq!(v.test_spans.len(), 1);
        let off = v.code.find("fs::write").unwrap();
        assert!(v.in_test(off));
        assert!(!v.in_test(0));
    }

    #[test]
    fn lint_allow_comments_are_parsed() {
        let src = "x(); // lint-allow: relaxed-ordering telemetry counter, no protocol\n\
                   y(); // lint-allow: nope\n";
        let v = FileView::new("rust/src/t.rs", src);
        assert_eq!(v.allows.len(), 2);
        assert_eq!(v.allows[0].line, 1);
        assert_eq!(v.allows[0].rule, "relaxed-ordering");
        assert!(v.allows[0].reason.starts_with("telemetry"));
        assert_eq!(v.allows[1].rule, "nope");
        assert_eq!(v.allows[1].reason, "");
    }

    #[test]
    fn bounded_find_respects_identifier_and_colon_boundaries() {
        let hay = "transport::fs::x std::fs::y fs::z inumx";
        let hits = find_bounded(hay, "fs::", false);
        assert_eq!(hits.len(), 1, "only the bare fs:: matches: {hits:?}");
        let hits = find_bounded(hay, "std::fs::", false);
        assert_eq!(hits.len(), 1);
        let hits = find_bounded(hay, "num", true);
        assert!(hits.is_empty(), "inumx must not match num: {hits:?}");
    }

    #[test]
    fn balanced_arg_spans_nested_parens() {
        let text = "num((a + b(c)) as f64) + 1";
        let open = text.find('(').unwrap();
        assert_eq!(balanced_arg(text, open), "(a + b(c)) as f64");
    }
}
