//! Per-rule fixture snippets: each rule gets a known-good source that
//! passes and a known-bad source that fails with the right rule id on
//! the right file:line — plus the meta checks (unknown lint-allow rule,
//! cfg(test) exemption) and the linchpin: the real tree lints clean.

use xtask::{lint_files, Finding};

fn lint_one(path: &str, src: &str) -> Vec<Finding> {
    lint_files(&[(path.to_string(), src.to_string())])
}

fn assert_clean(path: &str, src: &str) {
    let f = lint_one(path, src);
    assert!(f.is_empty(), "expected clean, got: {f:?}");
}

fn assert_finds(path: &str, src: &str, rule: &str, line: usize) {
    let f = lint_one(path, src);
    assert!(
        f.iter().any(|x| x.rule == rule && x.path == path && x.line == line),
        "expected {rule} at {path}:{line}, got: {f:?}"
    );
}

// ---- R1 fs-outside-seam ---------------------------------------------------

#[test]
fn r1_coordinator_fs_is_flagged() {
    let bad = "pub fn collect(p: &std::path::Path) {\n\
                   let _ = std::fs::read(p);\n\
               }\n";
    assert_finds("rust/src/coordinator/procs.rs", bad, "fs-outside-seam", 2);
}

#[test]
fn r1_transport_fs_is_fine_and_seam_reexports_are_fine() {
    let good = "pub fn collect(p: &std::path::Path) {\n\
                    let _ = std::fs::read(p);\n\
                }\n";
    assert_clean("rust/src/transport/fs.rs", good);
    // re-exporting the transport fs seam from the coordinator is the seam
    let reexport = "pub use crate::transport::fs::{checkpoint_path, collect_artifact};\n";
    assert_clean("rust/src/coordinator/procs.rs", reexport);
}

// ---- R2 final-path-create -------------------------------------------------

#[test]
fn r2_direct_final_artifact_write_is_flagged() {
    let bad = "pub fn publish(dir: &std::path::Path, bytes: &[u8]) {\n\
                   std::fs::write(dir.join(\"shards.json\"), bytes).unwrap();\n\
               }\n";
    assert_finds("rust/src/text/feed.rs", bad, "final-path-create", 2);
}

#[test]
fn r2_tmp_then_rename_is_fine() {
    let good = "pub fn publish(dir: &std::path::Path, bytes: &[u8]) {\n\
                    let tmp = dir.join(\"manifest.tmp\");\n\
                    std::fs::write(&tmp, bytes).unwrap();\n\
                    std::fs::rename(&tmp, dir.join(\"shards.json\")).unwrap();\n\
                }\n";
    assert_clean("rust/src/text/feed.rs", good);
}

// ---- R3 json-int-precision ------------------------------------------------

#[test]
fn r3_bare_integer_cast_into_num_is_flagged() {
    let bad = "pub fn row(n: u64) -> Json {\n\
                   num(n as f64)\n\
               }\n";
    assert_finds("rust/src/obs/report.rs", bad, "json-int-precision", 2);
    let bad_direct = "pub fn row(n: usize) -> Json {\n\
                      Json::Num(n as f64)\n\
                      }\n";
    assert_finds("rust/src/obs/report.rs", bad_direct, "json-int-precision", 2);
}

#[test]
fn r3_helpers_and_float_arithmetic_are_fine() {
    let good = "pub fn row(n: u64, secs: f64, bytes: u64) -> Json {\n\
                    obj(vec![\n\
                        (\"n\", inum(n)),\n\
                        (\"count\", u64s(n)),\n\
                        (\"rate\", num(bytes as f64 / secs / 1e6)),\n\
                        (\"lr\", fnum(0.025f32)),\n\
                    ])\n\
                }\n";
    assert_clean("rust/src/obs/report.rs", good);
}

// ---- R4 env-var-outside-env -----------------------------------------------

#[test]
fn r4_env_read_outside_util_env_is_flagged() {
    let bad = "pub fn knob() -> Option<String> {\n\
                   std::env::var(\"DW2V_LOG\").ok()\n\
               }\n";
    assert_finds("rust/src/coordinator/supervisor.rs", bad, "env-var-outside-env", 2);
}

#[test]
fn r4_util_env_is_the_home() {
    let good = "pub fn var(name: &str) -> Option<String> {\n\
                    std::env::var(name).ok()\n\
                }\n";
    assert_clean("rust/src/util/env.rs", good);
}

// ---- R5 nondeterministic-call ---------------------------------------------

#[test]
fn r5_wall_clock_in_deterministic_path_is_flagged() {
    let bad = "pub fn route() -> u64 {\n\
                   let t = std::time::SystemTime::now();\n\
                   0\n\
               }\n";
    assert_finds("rust/src/coordinator/divider.rs", bad, "nondeterministic-call", 2);
}

#[test]
fn r5_other_files_may_read_the_clock() {
    let good = "pub fn stamp() -> std::time::SystemTime {\n\
                    std::time::SystemTime::now()\n\
                }\n";
    assert_clean("rust/src/obs/journal.rs", good);
}

// ---- R6 unhandled-message -------------------------------------------------

const FRAME_OK: &str = "pub const MSG_REGISTER: u8 = 0x01;\n\
                        pub const MSG_GET_SHARD: u8 = 0x02;\n";

#[test]
fn r6_unhandled_frame_message_is_flagged() {
    let server = "fn handle(t: u8) {\n\
                      match t {\n\
                          frame::MSG_REGISTER => {}\n\
                          _ => {}\n\
                      }\n\
                  }\n";
    let f = lint_files(&[
        ("rust/src/transport/frame.rs".to_string(), FRAME_OK.to_string()),
        ("rust/src/transport/server.rs".to_string(), server.to_string()),
    ]);
    assert!(
        f.iter().any(|x| x.rule == "unhandled-message"
            && x.path == "rust/src/transport/frame.rs"
            && x.line == 2
            && x.msg.contains("MSG_GET_SHARD")),
        "got: {f:?}"
    );
}

#[test]
fn r6_fully_dispatched_frame_is_fine() {
    let server = "fn handle(t: u8) {\n\
                      match t {\n\
                          frame::MSG_REGISTER => {}\n\
                          frame::MSG_GET_SHARD => {}\n\
                          _ => {}\n\
                      }\n\
                  }\n";
    let f = lint_files(&[
        ("rust/src/transport/frame.rs".to_string(), FRAME_OK.to_string()),
        ("rust/src/transport/server.rs".to_string(), server.to_string()),
    ]);
    assert!(f.is_empty(), "got: {f:?}");
}

// ---- R7 relaxed-ordering --------------------------------------------------

#[test]
fn r7_undocumented_relaxed_is_flagged_and_allowlist_is_honored() {
    let bad = "use std::sync::atomic::{AtomicU64, Ordering};\n\
               pub fn bump(c: &AtomicU64) {\n\
                   c.fetch_add(1, Ordering::Relaxed);\n\
               }\n";
    assert_finds("rust/src/exec/channel.rs", bad, "relaxed-ordering", 3);
    assert_clean("rust/src/obs/metrics.rs", bad);
    assert_clean("rust/src/sgns/hogwild.rs", bad);
}

#[test]
fn r7_justified_relaxed_passes() {
    let good = "use std::sync::atomic::{AtomicU64, Ordering};\n\
                pub fn bump(c: &AtomicU64) {\n\
                    // lint-allow: relaxed-ordering monotonic telemetry counter\n\
                    c.fetch_add(1, Ordering::Relaxed);\n\
                }\n";
    assert_clean("rust/src/exec/channel.rs", good);
}

// ---- meta ------------------------------------------------------------------

#[test]
fn unknown_lint_allow_rule_is_itself_an_error() {
    let src = "pub fn f() {}\n// lint-allow: not-a-rule because reasons\n";
    let f = lint_one("rust/src/x.rs", src);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "bad-lint-allow");
    assert_eq!(f[0].line, 2);
    assert!(f[0].msg.contains("not-a-rule"));
}

#[test]
fn findings_inside_cfg_test_mods_are_exempt() {
    let src = "pub fn f() {}\n\
               #[cfg(test)]\n\
               mod tests {\n\
                   use std::sync::atomic::{AtomicU64, Ordering};\n\
                   fn g(c: &AtomicU64) {\n\
                       c.fetch_add(1, Ordering::Relaxed);\n\
                       let _ = std::env::var(\"DW2V_LOG\");\n\
                   }\n\
               }\n";
    assert_clean("rust/src/exec/channel.rs", src);
}

#[test]
fn strings_and_comments_cannot_trip_rules() {
    let src = "pub fn f() -> &'static str {\n\
                   // Ordering::Relaxed is discussed here, and std::env::var too\n\
                   \"Ordering::Relaxed env::var(\\\"DW2V_X\\\") num(x as f64)\"\n\
               }\n";
    assert_clean("rust/src/exec/channel.rs", src);
}

// ---- the linchpin: the shipped tree is clean --------------------------------

#[test]
fn the_real_tree_has_zero_unsuppressed_findings() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .and_then(|p| p.parent())
        .expect("xtask lives at <root>/rust/xtask")
        .to_path_buf();
    let (findings, _suppressed, files) = xtask::lint_tree(&root).expect("readable tree");
    assert!(files > 50, "tree walk looks broken: only {files} files");
    assert!(
        findings.is_empty(),
        "the tree must lint clean; run `cargo xtask lint`:\n{}",
        findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
