"""Layer-2 correctness: packed-state train step semantics.

Covers the state layout invariants the rust runtime depends on (pad row
stays zero, metrics counters, sentinel index mapping), kernel-vs-ref parity
of the full step, scan/unroll equivalence, and loss descent on a planted
co-occurrence structure.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.model import (
    ModelConfig,
    example_args,
    init_state,
    metrics,
    reference_train_many,
    similarity,
    train_many,
    train_step,
)

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(vocab=32, dim=8, batch=8, negatives=3, steps=3)


def random_batches(rng, cfg, pad_frac=0.0):
    centers = rng.integers(0, cfg.vocab, size=(cfg.steps, cfg.batch)).astype(np.int32)
    ctx = rng.integers(0, cfg.vocab, size=(cfg.steps, cfg.batch, cfg.k1)).astype(
        np.int32
    )
    weights = np.ones((cfg.steps, cfg.batch), np.float32)
    if pad_frac > 0:
        mask = rng.random(size=weights.shape) < pad_frac
        weights[mask] = 0.0
        centers[mask] = cfg.vocab  # padding sentinel
        ctx[mask] = cfg.vocab
    return centers, ctx, weights


def fresh_state(cfg, seed=0):
    state = init_state(cfg, jax.random.PRNGKey(seed))
    # Give C small random values too so context gradients are non-trivial.
    key = jax.random.PRNGKey(seed + 1)
    c = (jax.random.uniform(key, (cfg.vocab, cfg.dim)) - 0.5) / cfg.dim
    return state.at[cfg.vocab : 2 * cfg.vocab].set(c)


class TestStateLayout:
    def test_init_layout(self):
        state = init_state(CFG, jax.random.PRNGKey(0))
        assert state.shape == (CFG.rows, CFG.dim)
        np.testing.assert_array_equal(state[CFG.pad_row], 0.0)
        np.testing.assert_array_equal(state[CFG.metrics_row], 0.0)
        w = state[: CFG.vocab]
        assert float(jnp.abs(w).max()) <= 0.5 / CFG.dim + 1e-7

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_pad_row_stays_zero(self, seed):
        rng = np.random.default_rng(seed)
        state = fresh_state(CFG, seed % 97)
        centers, ctx, weights = random_batches(rng, CFG, pad_frac=0.5)
        lr = np.array([0.05], np.float32)
        out = train_many(CFG, state, centers, ctx, weights, lr)
        np.testing.assert_array_equal(np.asarray(out[CFG.pad_row]), 0.0)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_metrics_counters(self, seed):
        rng = np.random.default_rng(seed)
        state = fresh_state(CFG)
        centers, ctx, weights = random_batches(rng, CFG, pad_frac=0.3)
        lr = np.array([0.05], np.float32)
        out = train_many(CFG, state, centers, ctx, weights, lr)
        m = np.asarray(metrics(CFG, out))
        assert m[0] > 0.0  # loss accumulated
        np.testing.assert_allclose(m[1], weights.sum(), rtol=1e-6)
        np.testing.assert_allclose(m[2], CFG.steps)

    def test_padded_examples_leave_params_untouched(self):
        """A fully-padded macro-batch must only touch the metrics row."""
        state = fresh_state(CFG)
        centers = np.full((CFG.steps, CFG.batch), CFG.vocab, np.int32)
        ctx = np.full((CFG.steps, CFG.batch, CFG.k1), CFG.vocab, np.int32)
        weights = np.zeros((CFG.steps, CFG.batch), np.float32)
        out = train_many(CFG, state, centers, ctx, weights, np.array([0.1], np.float32))
        np.testing.assert_array_equal(
            np.asarray(out[: CFG.metrics_row]), np.asarray(state[: CFG.metrics_row])
        )


class TestStepSemantics:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_kernel_step_matches_ref_step(self, seed):
        rng = np.random.default_rng(seed)
        state = fresh_state(CFG, seed % 31)
        centers, ctx, weights = random_batches(rng, CFG, pad_frac=0.2)
        lr = np.array([0.05], np.float32)
        out_k = train_many(CFG, state, centers, ctx, weights, lr)
        out_r = reference_train_many(CFG, state, centers, ctx, weights, lr)
        np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_scan_equals_unrolled_single_steps(self, seed):
        rng = np.random.default_rng(seed)
        state = fresh_state(CFG, 3)
        centers, ctx, weights = random_batches(rng, CFG)
        lr = np.array([0.05], np.float32)
        out_scan = train_many(CFG, state, centers, ctx, weights, lr)
        out_seq = state
        for s in range(CFG.steps):
            out_seq = train_step(CFG, out_seq, centers[s], ctx[s], weights[s], lr)
        np.testing.assert_allclose(out_scan, out_seq, rtol=1e-5, atol=1e-6)

    def test_duplicate_indices_accumulate(self):
        """Scatter-add must accumulate duplicate center rows in a batch."""
        cfg = ModelConfig(vocab=8, dim=4, batch=4, negatives=1, steps=1)
        state = fresh_state(cfg, 11)
        centers = np.zeros((1, 4), np.int32)  # all the same center word
        ctx = np.arange(8, dtype=np.int32)[: cfg.k1 * 4].reshape(1, 4, cfg.k1) % 8
        weights = np.ones((1, 4), np.float32)
        lr = np.array([0.1], np.float32)
        out = train_many(cfg, state, centers, ctx, weights, lr)
        # apply the same batch one example at a time; the summed update of
        # row 0 must equal the batched scatter-add result
        seq = state
        for i in range(4):
            c1 = centers[:, i : i + 1]
            x1 = ctx[:, i : i + 1]
            w1 = weights[:, i : i + 1]
            cfg1 = ModelConfig(vocab=8, dim=4, batch=1, negatives=1, steps=1)
            # single-example steps from the SAME starting state, accumulated
            stepped = train_many(cfg1, state, c1, x1, w1, lr)
            seq = seq + (stepped - state)
        np.testing.assert_allclose(
            np.asarray(out[0]), np.asarray(seq[0]), rtol=1e-5, atol=1e-6
        )

    def test_loss_decreases_on_planted_structure(self):
        """Training on a fixed co-occurrence pattern reduces running loss."""
        cfg = ModelConfig(vocab=16, dim=8, batch=16, negatives=2, steps=8)
        rng = np.random.default_rng(0)
        state = fresh_state(cfg, 5)
        lr = np.array([0.5], np.float32)

        def planted(steps):
            centers = rng.integers(0, 8, size=(steps, cfg.batch)).astype(np.int32)
            pos = centers + 8  # word i always co-occurs with word i+8
            neg = rng.integers(0, 8, size=(steps, cfg.batch, cfg.negatives))
            ctx = np.concatenate([pos[:, :, None], neg], axis=2).astype(np.int32)
            return centers, ctx, np.ones((steps, cfg.batch), np.float32)

        losses = []
        for _ in range(6):
            before = float(metrics(cfg, state)[0])
            centers, ctx, weights = planted(cfg.steps)
            state = train_many(cfg, state, centers, ctx, weights, lr)
            after = float(metrics(cfg, state)[0])
            losses.append(after - before)
        assert losses[-1] < losses[0] * 0.9

    def test_example_args_shapes(self):
        specs = example_args(CFG)
        assert specs[0].shape == (CFG.rows, CFG.dim)
        assert specs[1].shape == (CFG.steps, CFG.batch)
        assert specs[2].shape == (CFG.steps, CFG.batch, CFG.k1)
        assert specs[4].shape == (1,)


class TestSimilarity:
    def test_cosine_values(self):
        cfg = ModelConfig(vocab=8, dim=4, batch=4, negatives=1, steps=1)
        state = jnp.zeros((cfg.rows, cfg.dim))
        state = state.at[0].set(jnp.array([1.0, 0, 0, 0]))
        state = state.at[1].set(jnp.array([2.0, 0, 0, 0]))  # same direction
        state = state.at[2].set(jnp.array([0, 3.0, 0, 0]))  # orthogonal
        q = np.array([0, 0], np.int32)
        cand = np.array([1, 2], np.int32)
        sims = np.asarray(similarity(cfg, state, q, cand))
        np.testing.assert_allclose(sims, [1.0, 0.0], atol=1e-6)

    def test_zero_vector_guard(self):
        cfg = ModelConfig(vocab=4, dim=4, batch=4, negatives=1, steps=1)
        state = jnp.zeros((cfg.rows, cfg.dim))
        sims = np.asarray(
            similarity(cfg, state, np.array([0], np.int32), np.array([1], np.int32))
        )
        assert np.isfinite(sims).all()
