"""Layer-1 correctness: Pallas SGNS kernel vs the pure-jnp oracle.

The hypothesis sweep drives the kernel across batch/dim/negative shapes and
dtypes and asserts allclose against kernels.ref; an independent jax.grad
cross-check pins the oracle itself to autodiff ground truth.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import sgns_dense_ref, sgns_loss_scalar
from compile.kernels.sgns import sgns_dense, vmem_footprint_bytes

jax.config.update("jax_platform_name", "cpu")


def random_inputs(rng, b, k1, d, scale=1.0, dtype=np.float32):
    w = rng.normal(size=(b, d), scale=scale).astype(dtype)
    c = rng.normal(size=(b, k1, d), scale=scale).astype(dtype)
    weight = rng.integers(0, 2, size=(b,)).astype(np.float32)
    return w, c, weight


class TestKernelVsRef:
    @settings(max_examples=25, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 4, 8, 16, 32]),
        k=st.integers(min_value=1, max_value=8),
        d=st.sampled_from([4, 8, 16, 32, 64]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref_shapes_sweep(self, b, k, d, seed):
        rng = np.random.default_rng(seed)
        w, c, weight = random_inputs(rng, b, k + 1, d)
        loss_k, gw_k, gc_k = sgns_dense(w, c, weight, block_b=b)
        loss_r, gw_r, gc_r = sgns_dense_ref(w, c, weight)
        np.testing.assert_allclose(loss_k, loss_r, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(gw_k, gw_r, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(gc_k, gc_r, rtol=1e-5, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(
        blocks=st.integers(min_value=1, max_value=4),
        block_b=st.sampled_from([2, 4, 8]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_grid_tiling_invariant(self, blocks, block_b, seed):
        """Result must not depend on the batch tile size."""
        rng = np.random.default_rng(seed)
        b = blocks * block_b
        w, c, weight = random_inputs(rng, b, 4, 16)
        loss_t, gw_t, gc_t = sgns_dense(w, c, weight, block_b=block_b)
        loss_f, gw_f, gc_f = sgns_dense(w, c, weight, block_b=b)
        np.testing.assert_allclose(loss_t, loss_f, rtol=1e-6)
        np.testing.assert_allclose(gw_t, gw_f, rtol=1e-6)
        np.testing.assert_allclose(gc_t, gc_f, rtol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(
        dtype=st.sampled_from([np.float32, np.float16]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_dtype_inputs_upcast(self, dtype, seed):
        """Lower-precision inputs are upcast to f32 inside both paths."""
        rng = np.random.default_rng(seed)
        w, c, weight = random_inputs(rng, 8, 3, 8, dtype=dtype)
        loss_k, gw_k, gc_k = sgns_dense(w, c, weight)
        loss_r, gw_r, gc_r = sgns_dense_ref(w, c, weight)
        assert loss_k.dtype == jnp.float32
        np.testing.assert_allclose(loss_k, loss_r, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(gw_k, gw_r, rtol=1e-4, atol=1e-5)

    def test_rejects_indivisible_block(self):
        rng = np.random.default_rng(0)
        w, c, weight = random_inputs(rng, 6, 3, 8)
        with pytest.raises(ValueError, match="not divisible"):
            sgns_dense(w, c, weight, block_b=4)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_zero_weight_examples_contribute_nothing(self, seed):
        rng = np.random.default_rng(seed)
        w, c, _ = random_inputs(rng, 8, 4, 16)
        weight = np.zeros((8,), np.float32)
        loss, gw, gc = sgns_dense(w, c, weight)
        assert float(jnp.abs(loss).max()) == 0.0
        assert float(jnp.abs(gw).max()) == 0.0
        assert float(jnp.abs(gc).max()) == 0.0

    def test_extreme_logits_are_finite(self):
        """softplus/sigmoid formulation must not overflow at |x| ~ 1e3."""
        b, k1, d = 4, 3, 8
        w = np.full((b, d), 10.0, np.float32)
        c = np.full((b, k1, d), 10.0, np.float32)  # logits = 800
        weight = np.ones((b,), np.float32)
        loss, gw, gc = sgns_dense(w, c, weight)
        assert np.isfinite(np.asarray(loss)).all()
        assert np.isfinite(np.asarray(gw)).all()
        assert np.isfinite(np.asarray(gc)).all()


class TestGradientsVsAutodiff:
    @settings(max_examples=15, deadline=None)
    @given(
        b=st.sampled_from([1, 4, 8]),
        k=st.integers(min_value=1, max_value=5),
        d=st.sampled_from([4, 16]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_ref_grads_match_jax_grad(self, b, k, d, seed):
        rng = np.random.default_rng(seed)
        w, c, weight = random_inputs(rng, b, k + 1, d)
        gw_auto, gc_auto = jax.grad(sgns_loss_scalar, argnums=(0, 1))(
            jnp.asarray(w), jnp.asarray(c), jnp.asarray(weight)
        )
        _, gw, gc = sgns_dense_ref(w, c, weight)
        np.testing.assert_allclose(gw, gw_auto, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(gc, gc_auto, rtol=1e-5, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.sampled_from([2, 8]),
        k=st.integers(min_value=1, max_value=5),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_kernel_grads_match_jax_grad(self, b, k, seed):
        rng = np.random.default_rng(seed)
        w, c, weight = random_inputs(rng, b, k + 1, 16)
        gw_auto, gc_auto = jax.grad(sgns_loss_scalar, argnums=(0, 1))(
            jnp.asarray(w), jnp.asarray(c), jnp.asarray(weight)
        )
        _, gw, gc = sgns_dense(w, c, weight)
        np.testing.assert_allclose(gw, gw_auto, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(gc, gc_auto, rtol=1e-5, atol=1e-6)


class TestLossSemantics:
    def test_known_value_zero_vectors(self):
        """All-zero embeddings: every pair has logit 0, loss = (1+k)·ln 2."""
        b, k1, d = 3, 4, 8
        loss, gw, gc = sgns_dense_ref(
            np.zeros((b, d), np.float32),
            np.zeros((b, k1, d), np.float32),
            np.ones((b,), np.float32),
        )
        np.testing.assert_allclose(loss, np.full((b,), k1 * np.log(2.0)), rtol=1e-6)
        np.testing.assert_allclose(gw, 0.0, atol=1e-7)

    def test_positive_alignment_reduces_loss(self):
        """Aligning w with the positive context lowers the loss."""
        d = 8
        w = np.ones((1, d), np.float32) * 0.5
        c_aligned = np.stack([[np.ones(d, np.float32), -np.ones(d, np.float32)]])
        c_opposed = np.stack([[-np.ones(d, np.float32), np.ones(d, np.float32)]])
        one = np.ones((1,), np.float32)
        loss_a, _, _ = sgns_dense_ref(w, c_aligned * 0.5, one)
        loss_o, _, _ = sgns_dense_ref(w, c_opposed * 0.5, one)
        assert float(loss_a[0]) < float(loss_o[0])

    def test_gradient_descends_loss(self):
        """One SGD step on the kernel's own gradients must reduce the loss."""
        rng = np.random.default_rng(7)
        w, c, weight = random_inputs(rng, 8, 5, 16, scale=0.3)
        weight = np.ones_like(weight)
        loss0, gw, gc = sgns_dense(w, c, weight)
        lr = 0.1
        loss1, _, _ = sgns_dense(w - lr * np.asarray(gw), c - lr * np.asarray(gc), weight)
        assert float(jnp.sum(loss1)) < float(jnp.sum(loss0))


def test_vmem_footprint_default_fits_budget():
    """Default block (256, k=5, d=64) must sit far below ~16 MiB VMEM."""
    assert vmem_footprint_bytes(256, 6, 64) < 2 * 1024 * 1024
