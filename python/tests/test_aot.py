"""AOT lowering: every preset lowers to parseable HLO text with a coherent
manifest. These tests are the build-time gate for the rust bridge."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from compile.aot import PRESETS, lower_config, manifest_entry, parse_cfg
from compile.model import ModelConfig


@pytest.fixture(scope="module")
def unit_hlos():
    return lower_config(PRESETS["unit"])


class TestLowering:
    def test_unit_preset_lowers(self, unit_hlos):
        assert set(unit_hlos) == {"train", "metrics", "sim"}
        for text in unit_hlos.values():
            assert text.startswith("HloModule"), text[:80]

    def test_train_hlo_mentions_expected_shapes(self, unit_hlos):
        cfg = PRESETS["unit"]
        text = unit_hlos["train"]
        assert f"f32[{cfg.rows},{cfg.dim}]" in text
        assert f"s32[{cfg.steps},{cfg.batch}]" in text
        # hot path must contain the scan (while) and scatter updates
        assert "while" in text
        assert "scatter" in text

    def test_no_64bit_id_serialization_needed(self, unit_hlos):
        """Guard the interchange decision: text must be ASCII-parseable."""
        unit_hlos["train"].encode("ascii")

    def test_custom_cfg_parse(self):
        cfg = parse_cfg("128,16,32,3,2:whatever")
        assert cfg == ModelConfig(vocab=128, dim=16, batch=32, negatives=3, steps=2)

    def test_manifest_entry_fields(self, unit_hlos):
        cfg = PRESETS["unit"]
        entry = manifest_entry(cfg, {k: f"{k}.hlo.txt" for k in unit_hlos})
        assert entry["rows"] == 2 * cfg.vocab + 2
        assert entry["pad_row"] == 2 * cfg.vocab
        assert entry["metrics_row"] == 2 * cfg.vocab + 1
        assert entry["vmem_block_bytes"] > 0


class TestCliEndToEnd:
    def test_writes_artifacts_and_manifest(self):
        with tempfile.TemporaryDirectory() as out:
            subprocess.run(
                [sys.executable, "-m", "compile.aot", "--out-dir", out,
                 "--preset", "unit"],
                check=True,
                cwd=os.path.join(os.path.dirname(__file__), ".."),
            )
            manifest = json.load(open(os.path.join(out, "manifest.json")))
            assert len(manifest["configs"]) == 1
            entry = manifest["configs"][0]
            for fname in entry["files"].values():
                path = os.path.join(out, fname)
                assert os.path.getsize(path) > 100
