"""Pure-jnp oracle for the SGNS dense core.

This is the correctness reference the Pallas kernel (sgns.py) is tested
against. It computes, for a micro-batch of gathered embeddings, the SGNS
loss and the dense gradients w.r.t. both the center vectors and the
(positive + negative) context vectors.

Shapes
------
w       [B, D]        gathered center-word embeddings
c       [B, K1, D]    gathered context embeddings; column 0 is the positive
                      context, columns 1..K1-1 are the K negative samples
weight  [B]           per-example weight (0.0 = padding, 1.0 = real)

Returns
-------
loss    [B]           weighted per-example SGNS loss
gw      [B, D]        d loss / d w     (already weighted)
gc      [B, K1, D]    d loss / d c     (already weighted)

The SGNS objective for one (w, c_pos, c_neg[0..K)) example is

    L = -log sigma(w . c_pos) - sum_j log sigma(-w . c_neg_j)
      =  softplus(-x_0)       + sum_j softplus(x_j)

with x_j = w . c_j. Its gradient w.r.t. x_j is (sigma(x_j) - label_j) with
label_0 = 1 and label_j = 0 otherwise, which is what both implementations
use.
"""

import jax
import jax.numpy as jnp


def sgns_dense_ref(w, c, weight):
    """Reference SGNS loss + gradients for a micro-batch.

    All math in float32; see module docstring for shapes.
    """
    w = w.astype(jnp.float32)
    c = c.astype(jnp.float32)
    weight = weight.astype(jnp.float32)
    k1 = c.shape[1]
    # logits[b, j] = w[b] . c[b, j]
    logits = jnp.einsum("bd,bjd->bj", w, c)
    labels = (jnp.arange(k1) == 0).astype(jnp.float32)[None, :]
    # loss = softplus(-x_pos) + sum_neg softplus(x_neg)
    per_pair = jax.nn.softplus(jnp.where(labels > 0, -logits, logits))
    loss = jnp.sum(per_pair, axis=1) * weight
    # dL/dx = sigma(x) - label
    g = (jax.nn.sigmoid(logits) - labels) * weight[:, None]
    gw = jnp.einsum("bj,bjd->bd", g, c)
    gc = g[:, :, None] * w[:, None, :]
    return loss, gw, gc


def sgns_loss_scalar(w, c, weight):
    """Summed scalar loss — used by tests to check gradients via jax.grad."""
    k1 = c.shape[1]
    logits = jnp.einsum("bd,bjd->bj", w, c)
    labels = (jnp.arange(k1) == 0).astype(jnp.float32)[None, :]
    per_pair = jax.nn.softplus(jnp.where(labels > 0, -logits, logits))
    return jnp.sum(jnp.sum(per_pair, axis=1) * weight)
