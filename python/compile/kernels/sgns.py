"""Layer-1 Pallas kernel: the SGNS dense core.

One fused kernel computes, per micro-batch block, the SGNS logits, the
per-example loss and both dense gradients. This is the compute hot spot of
the whole system — every (center, context+negatives) training pair flows
through it.

TPU mapping (see DESIGN.md §Hardware-Adaptation)
------------------------------------------------
* The grid tiles the batch dimension; each block's working set is
  ``block_b * (1 + 2*(K+1)) * D`` f32 values (w, c, gc and a few [block_b,
  K+1] temporaries), sized to sit comfortably in VMEM.
* The logits contraction ``w[b,:] . c[b,j,:]`` and the gradient
  contraction ``g[b,:] @ c[b,:,:]`` are expressed as jnp.einsum so the TPU
  lowering can feed the MXU; the outer product for gc uses the VPU.
* ``interpret=True`` is mandatory in this environment: the CPU PJRT plugin
  cannot execute Mosaic custom-calls, and interpret-mode lowers the kernel
  to plain HLO that any backend runs. The BlockSpec structure (and hence
  the VMEM schedule) is identical either way.

The kernel is validated against :mod:`.ref` by ``python/tests`` (pytest +
hypothesis shape/dtype sweeps and a jax.grad cross-check).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sgns_kernel(w_ref, c_ref, weight_ref, loss_ref, gw_ref, gc_ref):
    """Fused SGNS loss + gradients for one batch block.

    Refs (block shapes):
      w_ref      [BB, D]      center embeddings
      c_ref      [BB, K1, D]  contexts; col 0 positive, rest negatives
      weight_ref [BB]         example weights (0 = padding)
      loss_ref   [BB]         out: weighted per-example loss
      gw_ref     [BB, D]      out: d loss / d w
      gc_ref     [BB, K1, D]  out: d loss / d c
    """
    w = w_ref[...]
    c = c_ref[...]
    weight = weight_ref[...]
    k1 = c.shape[1]

    # logits[b, j] = w[b] . c[b, j] — batched contraction (MXU-friendly).
    logits = jnp.einsum("bd,bjd->bj", w, c, preferred_element_type=jnp.float32)
    labels = (jax.lax.broadcasted_iota(jnp.int32, (1, k1), 1) == 0).astype(
        jnp.float32
    )

    # Per-pair loss: softplus(-x) for the positive, softplus(x) for negatives.
    per_pair = jax.nn.softplus(jnp.where(labels > 0, -logits, logits))
    loss_ref[...] = jnp.sum(per_pair, axis=1) * weight

    # dL/dx_j = sigma(x_j) - label_j, scaled by the example weight.
    g = (jax.nn.sigmoid(logits) - labels) * weight[:, None]

    # gw[b] = sum_j g[b,j] * c[b,j]  — second batched contraction.
    gw_ref[...] = jnp.einsum("bj,bjd->bd", g, c, preferred_element_type=jnp.float32)
    # gc[b,j] = g[b,j] * w[b]        — outer product (VPU).
    gc_ref[...] = g[:, :, None] * w[:, None, :]


@functools.partial(jax.jit, static_argnames=("block_b",))
def sgns_dense(w, c, weight, *, block_b=None):
    """Pallas-kernel SGNS dense core.

    Args:
      w:      [B, D] float32 center embeddings.
      c:      [B, K1, D] float32 context embeddings (col 0 = positive).
      weight: [B] float32 per-example weights.
      block_b: batch tile size; must divide B. Defaults to min(B, 256).

    Returns:
      (loss [B], gw [B, D], gc [B, K1, D]) — see kernels.ref for semantics.
    """
    b, d = w.shape
    k1 = c.shape[1]
    if block_b is None:
        block_b = min(b, 256)
    if b % block_b != 0:
        raise ValueError(f"batch {b} not divisible by block_b {block_b}")
    grid = (b // block_b,)
    return pl.pallas_call(
        _sgns_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k1, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k1, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, k1, d), jnp.float32),
        ],
        interpret=True,
    )(w.astype(jnp.float32), c.astype(jnp.float32), weight.astype(jnp.float32))


def vmem_footprint_bytes(block_b, k1, d):
    """Estimated VMEM working set of one kernel block, in bytes.

    Counts the resident block inputs/outputs plus the [BB, K1] temporaries
    (logits, per_pair, g). Used by DESIGN.md §Perf and the aot manifest to
    sanity-check block sizes against the ~16 MiB/core VMEM budget.
    """
    f32 = 4
    tiles = (
        block_b * d  # w
        + block_b * k1 * d  # c
        + block_b  # weight
        + block_b  # loss
        + block_b * d  # gw
        + block_b * k1 * d  # gc
        + 3 * block_b * k1  # logits, per_pair, g
    )
    return tiles * f32
