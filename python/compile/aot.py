"""AOT compiler: lower the Layer-2 model to HLO text artifacts for rust.

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts                 # default set
    python -m compile.aot --out-dir ../artifacts --cfg 512,16,32,3,2:unit

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` crate links) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Per config three artifacts are produced:
  sgns_<name>.hlo.txt     train_many — the hot-path macro-step
  metrics_<name>.hlo.txt  metrics-row slice (loss counters)
  sim_<name>.hlo.txt      batched cosine similarity for the eval fast path
plus one shared ``manifest.json`` describing shapes, row layout and the
estimated Pallas VMEM footprint, which rust/src/runtime/artifacts.rs reads
to resolve a runtime config to an artifact.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.sgns import vmem_footprint_bytes
from .model import ModelConfig, example_args, metrics, similarity, train_many

SIM_Q = 256  # static query-batch size of the similarity artifact

# name -> (vocab, dim, batch, negatives, steps)
PRESETS = {
    "unit": ModelConfig(vocab=64, dim=8, batch=8, negatives=2, steps=2),
    "tiny": ModelConfig(vocab=2000, dim=32, batch=64, negatives=5, steps=4),
    # scan-length ablation partner of "tiny" (same shapes, steps=1) — used
    # by perf_hotpath to measure what the lax.scan macro-step buys
    "tiny_s1": ModelConfig(vocab=2000, dim=32, batch=64, negatives=5, steps=1),
    "default": ModelConfig(vocab=10000, dim=64, batch=256, negatives=5, steps=8),
}


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (return_tuple=False)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_config(cfg):
    """Lower all three entry points for one config; returns name->hlo text."""
    train = functools.partial(train_many, cfg)
    hlo_train = to_hlo_text(jax.jit(train).lower(*example_args(cfg)))

    state_spec = jax.ShapeDtypeStruct((cfg.rows, cfg.dim), jnp.float32)
    hlo_metrics = to_hlo_text(
        jax.jit(functools.partial(metrics, cfg)).lower(state_spec)
    )

    q_spec = jax.ShapeDtypeStruct((SIM_Q,), jnp.int32)
    hlo_sim = to_hlo_text(
        jax.jit(functools.partial(similarity, cfg)).lower(state_spec, q_spec, q_spec)
    )
    return {"train": hlo_train, "metrics": hlo_metrics, "sim": hlo_sim}


def manifest_entry(cfg, files):
    return {
        "name": cfg.name(),
        "vocab": cfg.vocab,
        "dim": cfg.dim,
        "batch": cfg.batch,
        "negatives": cfg.negatives,
        "steps": cfg.steps,
        "rows": cfg.rows,
        "pad_row": cfg.pad_row,
        "metrics_row": cfg.metrics_row,
        "sim_q": SIM_Q,
        "vmem_block_bytes": vmem_footprint_bytes(
            min(cfg.block_b, cfg.batch), cfg.k1, cfg.dim
        ),
        "files": files,
    }


def parse_cfg(spec):
    """Parse 'V,D,B,K,S[:name]' — name is informational only."""
    body = spec.split(":")[0]
    v, d, b, k, s = (int(x) for x in body.split(","))
    return ModelConfig(vocab=v, dim=d, batch=b, negatives=k, steps=s)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--preset",
        action="append",
        default=[],
        help="preset name (unit|tiny|default); repeatable",
    )
    ap.add_argument(
        "--cfg",
        action="append",
        default=[],
        help="custom config V,D,B,K,S; repeatable",
    )
    args = ap.parse_args()

    cfgs = [PRESETS[p] for p in args.preset] + [parse_cfg(c) for c in args.cfg]
    if not cfgs:
        cfgs = list(PRESETS.values())

    os.makedirs(args.out_dir, exist_ok=True)
    entries = []
    for cfg in cfgs:
        hlos = lower_config(cfg)
        files = {}
        for kind, text in hlos.items():
            fname = f"{kind}_{cfg.name()}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            files[kind] = fname
            print(f"  wrote {fname} ({len(text)} chars)")
        entries.append(manifest_entry(cfg, files))

    manifest = {"version": 1, "sim_q": SIM_Q, "configs": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json with {len(entries)} configs -> {args.out_dir}")


if __name__ == "__main__":
    main()
