"""Layer-2 JAX model: the SGNS train step over the packed parameter state.

The whole trainable state lives in ONE device array so the rust runtime can
chain ``execute_b`` calls with zero host round-trips (the CPU PJRT wrapper
returns multi-output computations as a single un-splittable tuple buffer, so
multi-array state would force a host copy every step — see
rust/src/bin/bridge_probe.rs):

    state: f32[2V + 2, D]
      rows [0, V)      W   — center/input embeddings
      rows [V, 2V)     C   — context/output embeddings
      row  2V          PAD — the all-zero padding row; padded examples index
                             it with weight 0, so it never changes
      row  2V+1        METRICS — running counters:
                             [0] sum of per-example losses
                             [1] number of weighted examples
                             [2] number of micro-steps executed
                             [3..] zero

One micro-step gathers the touched rows, runs the Layer-1 Pallas kernel for
the dense math, and applies SGD via scatter-add (duplicate indices in a
batch accumulate — deterministic, strictly stronger than Hogwild's racy
semantics that the paper's baseline relies on).

``train_many`` wraps ``steps`` micro-steps in a ``lax.scan`` so one PJRT
dispatch from rust covers a macro-batch; this is the artifact on the hot
path. ``metrics`` and ``similarity`` are tiny companion artifacts.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.ref import sgns_dense_ref
from .kernels.sgns import sgns_dense


@dataclass(frozen=True)
class ModelConfig:
    """Static shape configuration baked into one AOT artifact."""

    vocab: int  # V — vocabulary size
    dim: int  # D — embedding dimensionality
    batch: int  # B — examples per micro-step
    negatives: int  # K — negative samples per positive
    steps: int  # S — micro-steps per PJRT dispatch (scan length)
    block_b: int = 256  # Pallas batch tile

    @property
    def k1(self):
        return self.negatives + 1

    @property
    def rows(self):
        return 2 * self.vocab + 2

    @property
    def pad_row(self):
        return 2 * self.vocab

    @property
    def metrics_row(self):
        return 2 * self.vocab + 1

    def name(self):
        return (
            f"v{self.vocab}_d{self.dim}_b{self.batch}"
            f"_k{self.negatives}_s{self.steps}"
        )


def init_state(cfg, key):
    """Word2vec-style init: W ~ U(-0.5/D, 0.5/D), C = 0, pad/metrics = 0."""
    w = (
        jax.random.uniform(key, (cfg.vocab, cfg.dim), jnp.float32) - 0.5
    ) / cfg.dim
    rest = jnp.zeros((cfg.vocab + 2, cfg.dim), jnp.float32)
    return jnp.concatenate([w, rest], axis=0)


def _micro_step(cfg, use_kernel, state, centers, ctx, weights, lr):
    """One SGD micro-step over the packed state.

    centers: i32[B] rows into W (or pad_row); ctx: i32[B, K1] rows into C
    *relative to the C block* (i.e. 0..V, or pad sentinel V). weights: f32[B].
    """
    # Both index tensors use vocab-relative ids: 0..V-1 real, V = padding
    # sentinel. Centers map the sentinel to pad_row explicitly; contexts get
    # it for free (V + V == 2V == pad_row).
    w_rows = jnp.where(centers == cfg.vocab, cfg.pad_row, centers)
    c_rows = ctx + cfg.vocab
    w = state[w_rows]  # [B, D]
    c = state[c_rows]  # [B, K1, D]

    dense = sgns_dense if use_kernel else sgns_dense_ref
    if use_kernel:
        loss, gw, gc = dense(w, c, weights, block_b=min(cfg.block_b, cfg.batch))
    else:
        loss, gw, gc = dense(w, c, weights)

    state = state.at[w_rows].add(-lr * gw)
    state = state.at[c_rows].add(-lr * gc)
    metrics_delta = (
        jnp.zeros((cfg.dim,), jnp.float32)
        .at[0]
        .add(jnp.sum(loss))
        .at[1]
        .add(jnp.sum(weights))
        .at[2]
        .add(1.0)
    )
    state = state.at[cfg.metrics_row].add(metrics_delta)
    # Padded examples funnel their (zero-weighted, hence zero) gradients into
    # pad_row; keep it exactly zero regardless of float fuzz.
    state = state.at[cfg.pad_row].set(jnp.zeros((cfg.dim,), jnp.float32))
    return state, loss


def train_step(cfg, state, centers, ctx, weights, lr, *, use_kernel=True):
    """Single micro-step entry point (tests + the steps=1 artifact)."""
    state, _ = _micro_step(cfg, use_kernel, state, centers, ctx, weights, lr[0])
    return state


def train_many(cfg, state, centers, ctx, weights, lr, *, use_kernel=True):
    """S micro-steps per call via lax.scan — the hot-path artifact.

    Args:
      state:   f32[2V+2, D]
      centers: i32[S, B]
      ctx:     i32[S, B, K1]   (0..V-1 real, V = padding)
      weights: f32[S, B]
      lr:      f32[1]
    Returns: updated state.
    """

    def body(st, xs):
        cen, cx, wt = xs
        st, _ = _micro_step(cfg, use_kernel, st, cen, cx, wt, lr[0])
        return st, ()

    state, _ = jax.lax.scan(body, state, (centers, ctx, weights))
    return state


def metrics(cfg, state):
    """Slice out the metrics row (tiny companion artifact)."""
    return state[cfg.metrics_row]


def similarity(cfg, state, queries, candidates):
    """Cosine similarities between query and candidate W rows.

    queries: i32[Q], candidates: i32[Q] — returns f32[Q]. Used by the rust
    eval fast path to score similarity benchmarks on-device.
    """
    qw = state[queries]
    cw = state[candidates]
    qn = qw / jnp.maximum(jnp.linalg.norm(qw, axis=1, keepdims=True), 1e-9)
    cn = cw / jnp.maximum(jnp.linalg.norm(cw, axis=1, keepdims=True), 1e-9)
    return jnp.sum(qn * cn, axis=1)


def reference_train_many(cfg, state, centers, ctx, weights, lr):
    """Pure-jnp oracle of train_many (kernel replaced by ref) for pytest."""
    return train_many(cfg, state, centers, ctx, weights, lr, use_kernel=False)


@functools.lru_cache(maxsize=None)
def example_args(cfg):
    """ShapeDtypeStructs for lowering train_many."""
    return (
        jax.ShapeDtypeStruct((cfg.rows, cfg.dim), jnp.float32),
        jax.ShapeDtypeStruct((cfg.steps, cfg.batch), jnp.int32),
        jax.ShapeDtypeStruct((cfg.steps, cfg.batch, cfg.k1), jnp.int32),
        jax.ShapeDtypeStruct((cfg.steps, cfg.batch), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )
