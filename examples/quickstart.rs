//! Quickstart: the whole system in under a minute.
//!
//! Generates a small synthetic corpus, splits it into 4 sub-corpora with
//! the paper's Shuffle strategy, trains 4 SGNS sub-models fully
//! asynchronously, merges them with ALiR and scores the consensus on the
//! gold benchmark suite.
//!
//! Run with:  cargo run --release --example quickstart
//!
//! No setup needed: the default `auto` backend uses the PJRT/XLA AOT
//! artifacts when present (`make artifacts` + `--features xla`) and
//! falls back to the pure-rust native backend otherwise.

use dw2v::coordinator::leader;
use dw2v::eval::report;
use dw2v::runtime::{load_backend, Backend};
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::world::build_world;

fn main() -> Result<(), String> {
    // 1. configure a small experiment (all knobs on ExperimentConfig)
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 4000;
    cfg.vocab = 800;
    cfg.clusters = 16;
    cfg.dim = 32;
    cfg.epochs = 2;
    cfg.rate_percent = 25.0; // 4 sub-models
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.merge = MergeMethod::AlirPca;

    // 2. build the synthetic world (corpus + vocab + gold benchmarks)
    let world = build_world(&cfg);
    println!(
        "corpus: {} sentences, {} tokens, vocab {}",
        world.corpus.len(),
        world.corpus.total_tokens(),
        world.vocab.len()
    );

    // 3. resolve the compute backend (xla artifacts when loadable, else
    //    the pure-rust native engine — same protocol either way)
    let backend = load_backend(&cfg, world.vocab.len())?;
    let sh = backend.shape();
    println!("backend: {} (V={}, D={})", backend.name(), sh.vocab, sh.dim);

    // 4. divide -> train -> merge -> eval
    let rep = leader::run_pipeline(&cfg, &world.corpus, &world.vocab, &world.suite, &backend)?;

    println!(
        "\ntrained {} sub-models in {:.2}s ({} pairs), merged in {:.2}s",
        rep.train.submodels.len(),
        rep.train.train_secs,
        rep.train.pairs,
        rep.merge_secs
    );
    for (s, losses) in rep.train.epoch_loss.iter().enumerate() {
        let fmt: Vec<String> = losses.iter().map(|l| format!("{l:.4}")).collect();
        println!("  sub-model {s} epoch mean loss: [{}]", fmt.join(" -> "));
    }
    println!("\n{}", report::format_header(&rep.scores));
    println!("{}", report::format_row("Shuffle 25% + ALiR", &rep.scores));
    println!("\nquickstart OK");
    Ok(())
}
