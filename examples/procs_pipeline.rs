//! Multi-process pipeline demo: persist a synthetic corpus as shard
//! files, spawn one `dw2v train-worker` OS process per sub-model, merge
//! and evaluate whatever comes back.
//!
//!     cargo build --bin dw2v && cargo run --example procs_pipeline
//!
//! The workers share **nothing** at training time — no address space, no
//! parameters, no sockets. Their only inputs are the shard directory and
//! the `(seed, strategy, rate, epoch)` tuple that makes the stateless
//! divider agree across processes; their only output is a versioned
//! sub-model artifact. This is the paper's zero-synchronization claim
//! made literal.

use dw2v::coordinator::procs::{self, ProcsOptions};
use dw2v::eval::report;
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::world::build_world;

fn main() {
    let worker_exe = match procs::find_worker_exe() {
        Ok(exe) => exe,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };

    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 4000;
    cfg.vocab = 500;
    cfg.clusters = 12;
    cfg.dim = 24;
    cfg.epochs = 2;
    cfg.rate_percent = 25.0; // 4 worker processes
    cfg.min_count_base = 12.0;
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.merge = MergeMethod::AlirPca;

    // 1. persist the corpus — the only medium the workers ever touch
    let dir = std::env::temp_dir().join(format!("dw2v_procs_example_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create dir");
    let world = build_world(&cfg);
    world.corpus.write_sharded(&dir, 6).expect("write shards");
    std::fs::write(dir.join("vocab.tsv"), world.vocab.to_tsv()).expect("write vocab");
    println!(
        "persisted {} sentences / {} tokens as 6 shards in {}",
        world.corpus.len(),
        world.corpus.total_tokens(),
        dir.display()
    );

    // 2. spawn + monitor + collect + merge + eval
    let opts = ProcsOptions {
        worker_exe,
        shard_dir: dir.clone(),
        out_dir: dir.join("submodels"),
        extra_env: Vec::new(),
    };
    let rep = match procs::run_multiprocess(&cfg, &world.suite, &opts) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("multi-process run failed: {e}");
            let _ = std::fs::remove_dir_all(&dir);
            std::process::exit(1);
        }
    };

    println!("\nworker outcomes:");
    for o in &rep.outcomes {
        println!("  worker {}: {} ({:.2}s)", o.submodel, o.fate, o.secs);
    }
    println!(
        "train {:.2}s across {} processes | merge {:.2}s | eval {:.2}s",
        rep.train_secs,
        rep.outcomes.len(),
        rep.tail.merged.seconds,
        rep.tail.eval_secs
    );
    println!(
        "merged vocab: {} / {}",
        rep.tail.merged.embedding.present_count(),
        world.vocab.len()
    );
    println!("\n{}", report::format_header(&rep.tail.scores));
    println!(
        "{}",
        report::format_row("multi-process shuffle 25%", &rep.tail.scores)
    );
    let _ = std::fs::remove_dir_all(&dir);
}
