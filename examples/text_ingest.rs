//! Raw-text ingestion end-to-end: render a synthetic corpus to a plain
//! text file, stream it back through the two-pass ingestion pipeline
//! (tokenize → parallel vocab count → binary shards), train the full
//! paper pipeline on the re-ingested corpus, and score it on the gold
//! suite remapped into the ingested vocabulary. Because the text round
//! trip preserves the token stream, quality must match the direct
//! synthetic run — which this example prints side by side.
//!
//! Run with:  cargo run --release --example text_ingest

use dw2v::coordinator::leader;
use dw2v::eval::report;
use dw2v::gen::benchmarks::Benchmark;
use dw2v::runtime::{load_backend, Backend};
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::world::{build_world, TextWorldOptions, World};
use std::io::Write;

fn main() -> Result<(), String> {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 8_000;
    cfg.vocab = 800;
    cfg.clusters = 20;
    cfg.truth_dim = 12;
    cfg.dim = 24;
    cfg.epochs = 2;
    cfg.rate_percent = 25.0; // 4 sub-models
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.merge = MergeMethod::AlirPca;
    cfg.min_count_base = 8.0;

    println!("=== text_ingest: synthetic world -> raw text file ===");
    let world = build_world(&cfg);
    let dir = std::env::temp_dir().join(format!("dw2v_text_ingest_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
    let text_path = dir.join("corpus.txt");
    {
        let mut out = std::io::BufWriter::new(
            std::fs::File::create(&text_path).map_err(|e| e.to_string())?,
        );
        for sent in &world.corpus.sentences {
            let words: Vec<String> = sent.iter().map(|&t| format!("w{t}")).collect();
            writeln!(out, "{}.", words.join(" ")).map_err(|e| e.to_string())?;
        }
    }
    let bytes = std::fs::metadata(&text_path).map_err(|e| e.to_string())?.len();
    println!(
        "rendered {} sentences / {} tokens to {} ({:.1} MB)",
        world.corpus.len(),
        world.corpus.total_tokens(),
        text_path.display(),
        bytes as f64 / 1e6
    );

    println!("\n=== text_ingest: raw text -> vocab + shards -> corpus ===");
    let mut opts = TextWorldOptions::default();
    opts.ingest.min_count = 1;
    opts.ingest.workers = 4;
    opts.ingest.shard_tokens = 40_000; // force several shards
    opts.shard_dir = Some(dir.join("shards"));
    let (text_world, stats) = World::from_text(&text_path, &opts)?;
    println!("{}", stats.summary());

    // the gold suite speaks generator ids; translate through the word
    // strings into the ingested (frequency-ranked) id space
    let remap = |w: u32| text_world.vocab.id(&format!("w{w}"));
    let suite: Vec<Benchmark> = world.suite.iter().map(|b| b.remap_words(remap)).collect();

    println!("\n=== text_ingest: train on the ingested corpus ===");
    let backend = load_backend(&cfg, text_world.vocab.len())?;
    println!("backend: {}", backend.name());
    let rep = leader::run_pipeline(&cfg, &text_world.corpus, &text_world.vocab, &suite, &backend)?;
    println!(
        "pipeline: train {:.1}s ({} pairs), merge {:.1}s, eval {:.1}s",
        rep.train.train_secs, rep.train.pairs, rep.merge_secs, rep.eval_secs
    );

    println!("\n=== text_ingest: same run on the direct synthetic corpus ===");
    let backend2 = load_backend(&cfg, world.vocab.len())?;
    let rep_syn = leader::run_pipeline(&cfg, &world.corpus, &world.vocab, &world.suite, &backend2)?;

    println!("\n{}", report::format_header(&rep.scores));
    println!("{}", report::format_row("ingested text", &rep.scores));
    println!("{}", report::format_row("direct synthetic", &rep_syn.scores));
    println!(
        "\nmean score: ingested {:.3} vs synthetic {:.3}",
        report::mean_score(&rep.scores),
        report::mean_score(&rep_syn.scores)
    );

    let _ = std::fs::remove_dir_all(&dir);
    println!("\ntext_ingest OK");
    Ok(())
}
