//! END-TO-END driver (DESIGN.md §5, last row): the full paper system vs
//! the Hogwild baseline on one real (synthetic-corpus) workload.
//!
//! What it does — on the configured compute backend (PJRT when artifacts
//! load, the native rust engine otherwise):
//!   1. generates a corpus large enough to be a real training run
//!      (~50k sentences / ~1M tokens by default; DW2V_E2E_SCALE=full
//!      multiplies that ×4),
//!   2. trains the Hogwild baseline (the paper's 17.8 h comparator, scaled
//!      down), logging its wallclock,
//!   3. runs the paper pipeline: Shuffle 10% → 10 asynchronous backend
//!      sub-models × 3 epochs with per-epoch loss curves → ALiR merge,
//!   4. evaluates both on the 8 gold benchmarks and prints the headline
//!      table the paper's abstract summarizes (comparable-or-better
//!      quality at a fraction of the sequential cost).
//!
//! Run with:  cargo run --release --example e2e_pipeline
//! (uses XLA artifacts when present; falls back to the native backend)

use dw2v::coordinator::leader;
use dw2v::eval::report::{self, evaluate_suite};
use dw2v::runtime::{load_backend, Backend};
use dw2v::sgns::hogwild;
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::world::build_world;

fn main() -> Result<(), String> {
    let scale: usize = match std::env::var("DW2V_E2E_SCALE").as_deref() {
        Ok("full") => 4,
        _ => 1,
    };
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 50_000 * scale;
    cfg.vocab = 2000;
    cfg.clusters = 40;
    cfg.truth_dim = 16;
    cfg.dim = 32;
    cfg.epochs = 3;
    cfg.rate_percent = 10.0; // 10 sub-models — the paper's headline setting
    cfg.strategy = DivideStrategy::Shuffle;
    cfg.merge = MergeMethod::AlirPca;
    cfg.mappers = 2;

    println!("=== e2e: generating workload ===");
    let world = build_world(&cfg);
    println!(
        "corpus: {} sentences / {} tokens, vocab {}",
        world.corpus.len(),
        world.corpus.total_tokens(),
        world.vocab.len()
    );

    let backend = load_backend(&cfg, world.vocab.len())?;
    println!("backend: {}", backend.name());

    // ---- baseline: Hogwild (the paper's sequential-input comparator) ----
    println!("\n=== e2e: Hogwild baseline ===");
    let scfg = leader::sgns_config(&cfg);
    let (hog_emb, hog_stats) = hogwild::train(&world.corpus, &world.vocab, &scfg, 4, cfg.seed);
    println!(
        "hogwild: {:.1}s, {} pairs, final-epoch mean loss {:.4}",
        hog_stats.seconds, hog_stats.pairs, hog_stats.final_epoch_loss
    );
    let hog_scores = evaluate_suite(&hog_emb, &world.suite, cfg.seed);

    // ---- the paper system ------------------------------------------------
    println!("\n=== e2e: Shuffle 10% + ALiR (10 async sub-models) ===");
    let rep = leader::run_pipeline(&cfg, &world.corpus, &world.vocab, &world.suite, &backend)?;
    println!(
        "pipeline: train {:.1}s ({} pairs over {} sub-models, {} dispatches), merge {:.1}s ({} ALiR rounds), eval {:.1}s",
        rep.train.train_secs,
        rep.train.pairs,
        rep.train.submodels.len(),
        rep.train.dispatches,
        rep.merge_secs,
        rep.alir_rounds,
        rep.eval_secs
    );
    println!("loss curves (per sub-model, mean SGNS loss per epoch):");
    for (s, losses) in rep.train.epoch_loss.iter().enumerate() {
        let fmt: Vec<String> = losses.iter().map(|l| format!("{l:.4}")).collect();
        println!("  sub-model {s:>2}: [{}]", fmt.join(" -> "));
    }

    // ---- headline table ---------------------------------------------------
    println!("\n=== e2e: headline comparison ===");
    println!("{}", report::format_header(&hog_scores));
    println!("{}", report::format_row("Hogwild (baseline)", &hog_scores));
    println!("{}", report::format_row("Shuffle 10% + ALiR", &rep.scores));
    let per_model_train = rep.train.train_secs; // wall-clock of the whole round-based run
    println!(
        "\nwallclock: hogwild {:.1}s vs pipeline train {:.1}s + merge {:.1}s (merge is {:.1}% of train)",
        hog_stats.seconds,
        per_model_train,
        rep.merge_secs,
        100.0 * rep.merge_secs / per_model_train.max(1e-9)
    );
    let hog_mean = report::mean_score(&hog_scores);
    let pipe_mean = report::mean_score(&rep.scores);
    println!(
        "mean benchmark score: hogwild {hog_mean:.3} vs pipeline {pipe_mean:.3} ({:+.1}%)",
        100.0 * (pipe_mean - hog_mean) / hog_mean.abs().max(1e-9)
    );
    println!("\ne2e_pipeline OK");
    Ok(())
}
