//! Missing-vocabulary stress (the paper's §5.4 scenario, interactively):
//! train sub-models, then *systematically delete* a growing fraction of
//! benchmark words from random sub-models and watch how each merge method
//! copes. ALiR reconstructs deleted rows through the learned rotations;
//! Concat/PCA can only drop them.
//!
//! Run with:  cargo run --release --example missing_vocab
//! (uses XLA artifacts when present; falls back to the native backend)

use dw2v::coordinator::leader;
use dw2v::embedding::Embedding;
use dw2v::eval::report::{evaluate_suite, mean_score};
use dw2v::runtime::load_backend;
use dw2v::util::config::{DivideStrategy, ExperimentConfig, MergeMethod};
use dw2v::util::rng::Pcg64;
use dw2v::world::build_world;

/// Remove each word of `words` from at least one (random) sub-model;
/// with probability 1/2 from a second one too.
fn remove_words(models: &mut [Embedding], words: &[u32], rng: &mut Pcg64) {
    let n = models.len();
    for &w in words {
        let hits = 1 + rng.gen_range_usize(2);
        for _ in 0..hits {
            let m = rng.gen_range_usize(n);
            models[m].present[w as usize] = false;
            models[m].row_mut(w).fill(0.0);
        }
    }
}

fn main() -> Result<(), String> {
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 12_000;
    cfg.vocab = 800;
    cfg.clusters = 16;
    cfg.dim = 32;
    cfg.epochs = 2;
    cfg.rate_percent = 10.0;
    cfg.strategy = DivideStrategy::Shuffle;

    let world = build_world(&cfg);
    let backend = load_backend(&cfg, world.vocab.len())?;

    println!("training {} sub-models once…", cfg.num_submodels());
    let out = leader::train_submodels(&cfg, &world.corpus, &world.vocab, &backend)?;

    // all words the benchmarks touch
    let mut bench_words: Vec<u32> = world
        .suite
        .iter()
        .flat_map(|b| b.unique_words())
        .collect();
    bench_words.sort_unstable();
    bench_words.dedup();
    println!("{} unique benchmark words", bench_words.len());

    println!(
        "\n{:<10} {:<12} {:>12} {:>12} {:>14}",
        "removed", "method", "mean score", "OOV total", "vocab covered"
    );
    for frac in [0.0, 0.1, 0.5] {
        let mut rng = Pcg64::new(cfg.seed ^ 0xF1);
        let k = (bench_words.len() as f64 * frac) as usize;
        let removed: Vec<u32> = rng
            .sample_indices(bench_words.len(), k)
            .into_iter()
            .map(|i| bench_words[i])
            .collect();
        let mut models = out.submodels.clone();
        remove_words(&mut models, &removed, &mut rng);
        for method in [MergeMethod::Concat, MergeMethod::Pca, MergeMethod::AlirPca] {
            cfg.merge = method.clone();
            let merged = leader::merge_trained(&cfg, &models);
            let scores = evaluate_suite(&merged.embedding, &world.suite, cfg.seed);
            let oov: usize = scores.iter().map(|s| s.oov_words).sum();
            println!(
                "{:<10} {:<12} {:>12.3} {:>12} {:>14}",
                format!("{:.0}%", frac * 100.0),
                method.name(),
                mean_score(&scores),
                oov,
                merged.embedding.present_count()
            );
        }
    }
    println!("\nExpected shape (paper Figure 3): ALiR's mean score degrades only");
    println!("slightly with removal while Concat/PCA fall off sharply — ALiR");
    println!("reconstructs removed rows, the others drop them (higher OOV).");
    println!("\nmissing_vocab OK");
    Ok(())
}
