//! Serving a trained model: train → save → load → query, the full
//! post-training flow through the `serve/` subsystem.
//!
//! Trains a small SGNS model on a synthetic corpus, persists it the way
//! the pipeline would, loads it back into a [`ServeEngine`] (HNSW ANN
//! index + int8 quantized store), and then
//!   * answers nearest-neighbor and analogy queries,
//!   * fans a mixed batch out across the worker pool,
//!   * reconstructs a word deleted from the served model on the fly from
//!     rotated sub-model projections (the paper's missing-word scenario).
//!
//! Run with:  cargo run --release --example serve_queries

use dw2v::embedding::Embedding;
use dw2v::linalg::mat::Mat;
use dw2v::linalg::svd::svd;
use dw2v::serve::{Query, ServeConfig, ServeEngine};
use dw2v::sgns::config::SgnsConfig;
use dw2v::sgns::hogwild;
use dw2v::util::config::ExperimentConfig;
use dw2v::util::rng::Pcg64;
use dw2v::world::build_world;
use std::time::Instant;

fn main() -> Result<(), String> {
    // 1. train a small model (single-node hogwild keeps the example quick)
    let mut cfg = ExperimentConfig::default();
    cfg.sentences = 3000;
    cfg.vocab = 500;
    cfg.clusters = 10;
    let world = build_world(&cfg);
    let scfg = SgnsConfig {
        dim: 32,
        epochs: 2,
        ..Default::default()
    };
    println!("training on {} sentences…", world.corpus.len());
    let (emb, stats) = hogwild::train(&world.corpus, &world.vocab, &scfg, 2, cfg.seed);
    println!("trained in {:.2}s ({} pairs)", stats.seconds, stats.pairs);

    // 2. save + load — serving always starts from a persisted model
    let path = std::env::temp_dir().join(format!("serve_example_{}.bin", std::process::id()));
    emb.save(&path).map_err(|e| e.to_string())?;
    let served = Embedding::load(&path).map_err(|e| e.to_string())?;
    std::fs::remove_file(&path).ok();

    // 3. build the engine: ANN index + int8 store behind an Arc
    let t = Instant::now();
    let engine =
        ServeEngine::new(served.clone(), Some(world.vocab.clone()), ServeConfig::default());
    println!(
        "engine up in {:.2}s — {} words, {} index, int8 store {} KB",
        t.elapsed().as_secs_f64(),
        engine.index().len(),
        if engine.index().is_brute_force() { "exact-scan" } else { "HNSW" },
        engine.store_bytes() / 1024
    );

    // 4. single queries
    for probe in ["w3", "w42", "w117"] {
        let ns = engine.nearest_words(probe, 4)?;
        let cells: Vec<String> =
            ns.iter().map(|n| format!("{} {:.3}", n.word, n.score)).collect();
        println!("nearest({probe}):  {}", cells.join("  "));
    }
    let ns = engine.analogy("w1", "w2", "w10", 3)?;
    println!(
        "analogy(w1 : w2 :: w10 : ?):  {}",
        ns.iter().map(|n| n.word.clone()).collect::<Vec<_>>().join(" ")
    );

    // 5. a concurrent batch over the worker pool
    let batch: Vec<Query> = (0..200)
        .map(|i| Query::Nearest { word: format!("w{}", i % 500), k: 10 })
        .collect();
    let t = Instant::now();
    let results = engine.batch(&batch);
    let secs = t.elapsed().as_secs_f64();
    let ok = results.iter().filter(|r| r.is_ok()).count();
    println!(
        "batch: {ok}/{} queries answered in {:.3}s ({:.0} qps)",
        batch.len(),
        secs,
        batch.len() as f64 / secs.max(1e-9)
    );

    // 6. missing-word reconstruction: delete w7 from the served model,
    //    attach two rotated "sub-models" that still have it
    let dim = served.dim;
    let mut rng = Pcg64::new(cfg.seed ^ 0x5E);
    let truth_mat = Mat::from_f32(served.vocab, dim, &served.data);
    let submodels: Vec<Embedding> = (0..2)
        .map(|_| {
            let a = Mat::from_vec(dim, dim, (0..dim * dim).map(|_| rng.gen_gauss()).collect());
            let sv = svd(&a);
            let rot = sv.u.matmul(&sv.v.transpose());
            Embedding::from_rows(served.vocab, dim, truth_mat.matmul(&rot).to_f32())
        })
        .collect();
    let mut lossy = served.clone();
    let deleted = world.vocab.id("w7").expect("w7 in vocab");
    lossy.present[deleted as usize] = false;
    lossy.row_mut(deleted).fill(0.0);
    let engine2 = ServeEngine::with_submodels(
        lossy,
        Some(world.vocab.clone()),
        ServeConfig::default(),
        submodels,
    );
    let ns = engine2.nearest_words("w7", 4)?;
    println!(
        "nearest(w7, reconstructed from sub-models):  {}",
        ns.iter()
            .map(|n| format!("{} {:.3}", n.word, n.score))
            .collect::<Vec<_>>()
            .join("  ")
    );

    println!("\nserve_queries OK");
    Ok(())
}
